//! Row-major dense matrix used as the canonical vector-set storage.

use std::sync::Arc;

/// A dense, row-major `rows × cols` matrix of `f32`.
///
/// The vector set `S = {v_1, …, v_n}` of a MIPS instance is stored as one
/// `Matrix` with `rows = n`, `cols = N`; row `i` is vector `v_i`. Rows are
/// contiguous so partial dot products over coordinate ranges are cache-
/// friendly, matching the paper's cost model where a "pull" touches one
/// coordinate of one row.
///
/// Storage is shared (`Arc`) and a matrix may be a *row-range view* into
/// a larger backing buffer ([`Matrix::view_rows`]): `start` is the
/// element offset of row 0. Views are how contiguous dataset shards
/// ([`crate::data::shard::ShardedMatrix`]) stay zero-copy — every shard
/// reads the very same bytes as the unsharded matrix, which is what
/// makes sharded exact scoring byte-identical to unsharded.
#[derive(Clone, Debug)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    /// Element offset of row 0 inside `data` (non-zero only for views).
    start: usize,
    data: Arc<Vec<f32>>,
}

/// Equality is by shape and contents — a view equals a fresh copy of the
/// same rows regardless of where either lives in its backing buffer.
impl PartialEq for Matrix {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.as_slice() == other.as_slice()
    }
}

impl Matrix {
    /// Build from a flat row-major buffer. Panics if `data.len() != rows*cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            rows * cols,
            "Matrix::from_vec: buffer len {} != {rows}x{cols}",
            data.len()
        );
        Self { rows, cols, start: 0, data: Arc::new(data) }
    }

    /// Zero-copy view of the contiguous row range `[first, first + len)`:
    /// shares storage with `self` (no copy, no allocation beyond the
    /// `Arc` bump). Panics if the range exceeds the matrix.
    pub fn view_rows(&self, first: usize, len: usize) -> Matrix {
        assert!(
            first + len <= self.rows,
            "view_rows: [{first}, {}) out of {} rows",
            first + len,
            self.rows
        );
        Matrix {
            rows: len,
            cols: self.cols,
            start: self.start + first * self.cols,
            data: self.data.clone(),
        }
    }

    /// True when `self` shares backing storage with `other` (both are
    /// views of — or clones of — one buffer).
    pub fn shares_storage(&self, other: &Matrix) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::from_vec(rows, cols, vec![0.0; rows * cols])
    }

    /// Build from a closure `f(row, col) -> value`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self::from_vec(rows, cols, data)
    }

    /// Build by stacking rows. Panics if rows have unequal lengths.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let n = rows.len();
        let cols = rows.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(n * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "Matrix::from_rows: ragged rows");
            data.extend_from_slice(r);
        }
        Self::from_vec(n, cols, data)
    }

    /// Number of rows (vectors).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (dimension `N`).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let start = self.start + i * self.cols;
        &self.data[start..start + self.cols]
    }

    /// Element access.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[self.start + r * self.cols + c]
    }

    /// The flat row-major buffer of this matrix (for a view: just the
    /// viewed rows).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data[self.start..self.start + self.rows * self.cols]
    }

    /// Borrow the contiguous row range `[first, first + len)` as one
    /// flat slice — the input shape of the blocked
    /// [`crate::linalg::dot_rows`] kernel. Panics if the range exceeds
    /// the matrix.
    #[inline]
    pub fn row_block(&self, first: usize, len: usize) -> &[f32] {
        assert!(
            first + len <= self.rows,
            "row_block: [{first}, {}) out of {} rows",
            first + len,
            self.rows
        );
        let s = self.start + first * self.cols;
        &self.data[s..s + len * self.cols]
    }

    /// Iterator over rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        (0..self.rows).map(move |i| self.row(i))
    }

    /// Matrix-vector product `self * q` (each row dotted with `q`).
    pub fn matvec(&self, q: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.matvec_into(q, &mut out);
        out
    }

    /// [`Matrix::matvec`] into a caller-owned buffer (cleared first) —
    /// the allocation-free variant the execution core uses. Runs the
    /// blocked [`crate::linalg::dot_rows`] kernel over the whole
    /// row-major buffer (bit-identical per row to [`crate::linalg::dot`]).
    pub fn matvec_into(&self, q: &[f32], out: &mut Vec<f32>) {
        assert_eq!(q.len(), self.cols, "matvec: dim mismatch");
        out.clear();
        out.resize(self.rows, 0.0);
        super::dot_rows(self.as_slice(), self.cols, q, out);
    }

    /// A new matrix with the given rows gathered (copied) in order.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Matrix::from_vec(idx.len(), self.cols, data)
    }

    /// A new matrix whose columns are permuted: `out[r][c] = self[r][perm[c]]`.
    ///
    /// Used by BOUNDEDME to pre-permute coordinates once per query so that
    /// "sampling without replacement" becomes contiguous scans (see
    /// DESIGN.md §Hardware-Adaptation).
    pub fn permute_cols(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.cols);
        let mut data = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut data[r * self.cols..(r + 1) * self.cols];
            for (c, &p) in perm.iter().enumerate() {
                dst[c] = src[p];
            }
        }
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Min and max over all elements; `(0, 0)` for an empty matrix.
    pub fn min_max(&self) -> (f32, f32) {
        if self.as_slice().is_empty() {
            return (0.0, 0.0);
        }
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in self.as_slice() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    }

    /// Maximum L2 norm over rows (used by LSH-MIPS's Euclidean transform).
    pub fn max_row_norm(&self) -> f32 {
        self.iter_rows().map(super::norm).fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn shape_and_access() {
        let m = m();
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    #[should_panic]
    fn bad_buffer_panics() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn from_fn_and_rows() {
        let a = Matrix::from_fn(2, 2, |r, c| (r * 2 + c) as f32);
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![2.0, 3.0]]);
        assert_eq!(a, b);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = m();
        let out = m.matvec(&[1.0, 0.0, -1.0]);
        assert_eq!(out, vec![-2.0, -2.0]);
    }

    #[test]
    fn gather_and_permute() {
        let m = m();
        let g = m.gather_rows(&[1, 0, 1]);
        assert_eq!(g.row(0), &[4.0, 5.0, 6.0]);
        assert_eq!(g.rows(), 3);
        let p = m.permute_cols(&[2, 0, 1]);
        assert_eq!(p.row(0), &[3.0, 1.0, 2.0]);
    }

    #[test]
    fn min_max_and_norms() {
        let m = m();
        assert_eq!(m.min_max(), (1.0, 6.0));
        let expected = (16.0f32 + 25.0 + 36.0).sqrt();
        assert!((m.max_row_norm() - expected).abs() < 1e-6);
        assert_eq!(Matrix::zeros(0, 0).min_max(), (0.0, 0.0));
    }

    #[test]
    fn clone_shares_storage() {
        let m = m();
        let c = m.clone();
        assert!(std::ptr::eq(m.as_slice().as_ptr(), c.as_slice().as_ptr()));
        assert!(m.shares_storage(&c));
    }

    #[test]
    fn view_rows_is_zero_copy_and_correct() {
        let m = Matrix::from_fn(5, 3, |r, c| (r * 3 + c) as f32);
        let v = m.view_rows(2, 2);
        assert_eq!((v.rows(), v.cols()), (2, 3));
        assert_eq!(v.row(0), m.row(2));
        assert_eq!(v.get(1, 2), m.get(3, 2));
        assert_eq!(v.as_slice(), &m.as_slice()[6..12]);
        // Same bytes, not a copy.
        assert!(std::ptr::eq(v.row(0).as_ptr(), m.row(2).as_ptr()));
        assert!(v.shares_storage(&m));
        // Views of views compose.
        let vv = v.view_rows(1, 1);
        assert_eq!(vv.row(0), m.row(3));
        // min_max / matvec respect the view bounds.
        assert_eq!(v.min_max(), (6.0, 11.0));
        assert_eq!(v.matvec(&[1.0, 0.0, 0.0]), vec![6.0, 9.0]);
    }

    #[test]
    fn row_block_is_contiguous_and_view_aware() {
        let m = Matrix::from_fn(6, 3, |r, c| (r * 3 + c) as f32);
        assert_eq!(m.row_block(1, 2), &m.as_slice()[3..9]);
        assert_eq!(m.row_block(0, 6), m.as_slice());
        assert!(m.row_block(6, 0).is_empty());
        // On a view, blocks are relative to the view's rows but the
        // same backing bytes.
        let v = m.view_rows(2, 3);
        assert_eq!(v.row_block(1, 2), &m.as_slice()[9..15]);
        assert!(std::ptr::eq(v.row_block(0, 1).as_ptr(), m.row(2).as_ptr()));
    }

    #[test]
    #[should_panic]
    fn row_block_out_of_range_panics() {
        m().row_block(1, 2);
    }

    #[test]
    fn view_equals_copy_of_same_rows() {
        let m = Matrix::from_fn(4, 2, |r, c| (r * 2 + c) as f32);
        let view = m.view_rows(1, 2);
        let copy = m.gather_rows(&[1, 2]);
        assert_eq!(view, copy);
        assert_ne!(view, m.view_rows(0, 2));
    }

    #[test]
    #[should_panic]
    fn view_rows_out_of_range_panics() {
        m().view_rows(1, 2);
    }

    #[test]
    fn empty_view_is_fine() {
        let m = m();
        let v = m.view_rows(2, 0);
        assert_eq!(v.rows(), 0);
        assert!(v.as_slice().is_empty());
        assert_eq!(v.min_max(), (0.0, 0.0));
    }
}
