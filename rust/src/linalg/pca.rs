//! Principal component analysis via power iteration with deflation.
//!
//! Substrate for the PCA-MIPS baseline (Bachrach et al. 2014): the PCA
//! tree splits on the top principal directions of the (transformed)
//! dataset. We implement covariance-free power iteration: each iteration
//! computes `w ← Aᵀ(A w)` on the centered data, never materializing the
//! `N×N` covariance.

use super::{axpy, dot, normalize, Matrix, Rng};

/// Result of a PCA run: `components` are unit-norm rows (principal
/// directions, most significant first), `mean` is the column mean that
/// was subtracted, `eigenvalues` are the corresponding variances.
#[derive(Clone, Debug)]
pub struct Pca {
    /// `k × N` matrix of principal directions (rows, unit norm).
    pub components: Matrix,
    /// Column means of the input (length `N`).
    pub mean: Vec<f32>,
    /// Variance captured by each component.
    pub eigenvalues: Vec<f32>,
}

impl Pca {
    /// Project a vector onto component `c` (after centering).
    ///
    /// Deliberately a fused `(x−μ)·c` loop rather than the distributed
    /// `x·c − μ·c` form: for off-center data (mean magnitude ≫ spread)
    /// the distributed form subtracts two large dots and
    /// catastrophically cancels, while the fused sum of small centered
    /// terms stays accurate. This is therefore intentionally *not* part
    /// of the `linalg::simd` dot funnel; LLVM auto-vectorizes the shape
    /// well on its own.
    pub fn project(&self, x: &[f32], c: usize) -> f32 {
        let comp = self.components.row(c);
        let mut s = 0f32;
        for i in 0..x.len() {
            s += (x[i] - self.mean[i]) * comp[i];
        }
        s
    }
}

/// Compute the top-`k` principal components of `data` with power
/// iteration + deflation.
///
/// * `iters` power iterations per component (30 is plenty for tree
///   splitting purposes — we need directions, not eigenvalues to 1e-12).
/// * Deterministic given `seed`.
pub fn pca(data: &Matrix, k: usize, iters: usize, seed: u64) -> Pca {
    let n = data.rows();
    let d = data.cols();
    let k = k.min(d).min(n.max(1));
    let mut rng = Rng::new(seed);

    // Column means.
    let mut mean = vec![0f32; d];
    for row in data.iter_rows() {
        for (m, &x) in mean.iter_mut().zip(row) {
            *m += x;
        }
    }
    if n > 0 {
        let inv = 1.0 / n as f32;
        for m in mean.iter_mut() {
            *m *= inv;
        }
    }

    let mut comps: Vec<Vec<f32>> = Vec::with_capacity(k);
    let mut eigs = Vec::with_capacity(k);
    // Scratch for centered row.
    let mut centered = vec![0f32; d];

    for _ in 0..k {
        let mut w = rng.gaussian_vec(d);
        normalize(&mut w);
        let mut lambda = 0f32;
        for _ in 0..iters {
            // v = A_centered^T (A_centered w), deflated against previous comps.
            let mut v = vec![0f32; d];
            for row in data.iter_rows() {
                for i in 0..d {
                    centered[i] = row[i] - mean[i];
                }
                // Deflate the row against found components.
                for c in comps.iter() {
                    let proj = dot(&centered, c);
                    axpy(-proj, c, &mut centered);
                }
                let s = dot(&centered, &w);
                axpy(s, &centered, &mut v);
            }
            // Re-orthogonalize for numerical safety.
            for c in comps.iter() {
                let proj = dot(&v, c);
                axpy(-proj, c, &mut v);
            }
            lambda = normalize(&mut v);
            if lambda == 0.0 {
                // Degenerate direction (rank exhausted): keep previous w.
                break;
            }
            w = v;
        }
        eigs.push(if n > 0 { lambda / n as f32 } else { 0.0 });
        comps.push(w);
    }

    Pca { components: Matrix::from_rows(&comps), mean, eigenvalues: eigs }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dataset stretched along a known direction.
    fn stretched(n: usize, d: usize, dir: &[f32], seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let t = rng.gaussian() as f32 * 10.0;
            let mut row: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32 * 0.1).collect();
            for (r, &u) in row.iter_mut().zip(dir) {
                *r += t * u;
            }
            rows.push(row);
        }
        Matrix::from_rows(&rows)
    }

    #[test]
    fn recovers_dominant_direction() {
        let d = 16;
        let mut dir = vec![0f32; d];
        dir[3] = 0.6;
        dir[7] = 0.8;
        let data = stretched(400, d, &dir, 11);
        let p = pca(&data, 1, 50, 1);
        let c = p.components.row(0);
        let cosine = dot(c, &dir).abs();
        assert!(cosine > 0.99, "cosine={cosine}");
        assert!(p.eigenvalues[0] > 10.0);
    }

    #[test]
    fn components_are_orthonormal() {
        let mut rng = Rng::new(3);
        let data = Matrix::from_fn(200, 12, |_, _| rng.gaussian() as f32);
        let p = pca(&data, 4, 40, 2);
        for i in 0..4 {
            let ci = p.components.row(i);
            assert!((super::super::norm(ci) - 1.0).abs() < 1e-3);
            for j in 0..i {
                let c = dot(ci, p.components.row(j)).abs();
                assert!(c < 1e-2, "components {i},{j} not orthogonal: {c}");
            }
        }
    }

    #[test]
    fn eigenvalues_descending() {
        let mut rng = Rng::new(5);
        // Anisotropic data: per-column scales decreasing.
        let data = Matrix::from_fn(300, 8, |_, c| {
            rng.gaussian() as f32 * (8 - c) as f32
        });
        let p = pca(&data, 3, 60, 7);
        assert!(p.eigenvalues[0] >= p.eigenvalues[1]);
        assert!(p.eigenvalues[1] >= p.eigenvalues[2]);
    }

    #[test]
    fn project_centers_data() {
        let data = Matrix::from_rows(&[vec![1.0, 1.0], vec![3.0, 3.0]]);
        let p = pca(&data, 1, 30, 9);
        // Projections of the two points must be symmetric about 0.
        let a = p.project(data.row(0), 0);
        let b = p.project(data.row(1), 0);
        assert!((a + b).abs() < 1e-4, "a={a} b={b}");
    }

    #[test]
    fn handles_rank_deficient() {
        // All rows identical: zero variance, should not panic / NaN.
        let data = Matrix::from_rows(&vec![vec![2.0; 6]; 10]);
        let p = pca(&data, 3, 20, 13);
        for &e in &p.eigenvalues {
            assert!(e.abs() < 1e-6);
        }
        for r in 0..3 {
            for &v in p.components.row(r) {
                assert!(v.is_finite());
            }
        }
    }
}
