//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component of the system (dataset generation, LSH
//! projections, bandit pull order, workload arrival) is seeded through
//! this module so that experiments are exactly reproducible. We use
//! xoshiro256** seeded via SplitMix64, the standard recommendation of
//! Blackman & Vigna.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-query / per-worker
    /// streams). Deterministic in `(self_seed_state, salt)`.
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` via Lemire's method. Panics on 0.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "next_below(0)");
        // 128-bit multiply-shift; bias is negligible for our bounds and
        // rejected anyway below.
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard Gaussian via Box–Muller (pair-cached).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponential with rate `lambda` (used by the Poisson arrival
    /// workload generator in the serving benches).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `s`, via
    /// inverse-CDF on a precomputed table-free rejection scheme
    /// (Devroye). Good enough for workload skew.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Rejection sampling from the Zipf(s) distribution, Devroye p.551.
        if s <= 1.0 + 1e-9 {
            // Fall back to inverse CDF via harmonic approximation.
            let hn = (n as f64).ln() + 0.5772156649;
            let u = self.next_f64() * hn;
            let k = (u.exp() - 0.5).floor().max(0.0) as usize;
            return k.min(n - 1);
        }
        let b = 2.0f64.powf(s - 1.0);
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = (u.powf(-1.0 / (s - 1.0))).floor();
            let t = (1.0 + 1.0 / x).powf(s - 1.0);
            if x >= 1.0 && v * x * (t - 1.0) / (b - 1.0) <= t / b {
                let k = x as usize - 1;
                if k < n {
                    return k;
                }
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Vector of standard Gaussians.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.next_below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(8);
        let n = 1000;
        let draws: Vec<usize> = (0..20_000).map(|_| r.zipf(n, 1.2)).collect();
        assert!(draws.iter().all(|&k| k < n));
        let head = draws.iter().filter(|&&k| k < 10).count() as f64 / draws.len() as f64;
        assert!(head > 0.3, "zipf head mass = {head}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let m: f64 = (0..20_000).map(|_| r.exponential(2.0)).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.03, "mean={m}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
