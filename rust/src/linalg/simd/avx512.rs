//! AVX-512F backend (x86-64, 512-bit lanes).
//!
//! Every public entry is a safe wrapper over a `#[target_feature]`
//! kernel. SAFETY: the wrappers are sound because [`TABLE`] is only
//! selectable by the dispatcher after `is_x86_feature_detected!`
//! confirms `avx512f` **and** `avx2`+`fma` on the running CPU — the
//! gather kernel executes the AVX2 `vgatherdps`, and detection must
//! not assume AVX2 from AVX512F (hypervisors can mask them
//! independently).
//!
//! Accumulation order (the per-row contract shared by `dot`, `dot_rows`
//! and `partial_dot_rows`, which the exact-path bit-identity tests pin):
//! two 16-lane FMA accumulators over 32-float chunks, one optional
//! 16-float chunk into the first accumulator, a fixed horizontal
//! reduction of `acc0 + acc1`, then a sequential scalar tail. The
//! blocked kernels process **8 rows per pass** sharing each query
//! register load — 16 row accumulators plus 2 query registers sit
//! comfortably inside the 32 zmm registers.

use super::KernelTable;
use core::arch::x86_64::*;

pub(super) static TABLE: KernelTable = KernelTable {
    isa: "avx512",
    dot,
    axpy,
    dist_sq,
    norm_sq,
    dot_rows,
    partial_dot_rows,
    gather,
};

fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // min() mirrors the scalar backend's zip-truncation semantics, so a
    // release-mode length mismatch degrades identically instead of
    // reading out of bounds.
    let n = a.len().min(b.len());
    // SAFETY: table selected only after avx512f detection (module
    // docs); n is within both slices.
    unsafe { dot_512(a.as_ptr(), b.as_ptr(), n) }
}

fn norm_sq(a: &[f32]) -> f32 {
    // SAFETY: as above.
    unsafe { dot_512(a.as_ptr(), a.as_ptr(), a.len()) }
}

fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { axpy_512(alpha, x, y) }
}

fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: as above.
    unsafe { dist_sq_512(a, b) }
}

fn dot_rows(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    // Real asserts, not debug: the unsafe kernel reads out.len()*dim
    // floats from `block`, so a release-mode length mismatch from safe
    // code must panic (like the scalar backend's slicing would), not
    // read out of bounds.
    assert_eq!(block.len(), out.len() * dim, "dot_rows: block/out shape mismatch");
    assert_eq!(q.len(), dim, "dot_rows: query dim mismatch");
    // SAFETY: as above; shapes verified.
    unsafe { dot_rows_512(block, dim, q, out) }
}

fn partial_dot_rows(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    // Real asserts: the unsafe kernel reads q.len() floats from every
    // row pointer.
    assert_eq!(rows.len(), out.len(), "partial_dot_rows: rows/out mismatch");
    assert!(
        rows.iter().all(|r| r.len() == q.len()),
        "partial_dot_rows: row/query length mismatch"
    );
    // SAFETY: as above; shapes verified.
    unsafe { partial_dot_rows_512(rows, q, out) }
}

fn gather(src: &[f32], idx: &[u32], out: &mut [f32]) {
    // Real asserts: the hardware gather reads `src` unchecked once the
    // indices are validated.
    assert_eq!(idx.len(), out.len(), "gather: idx/out length mismatch");
    assert!(
        idx.iter().all(|&j| (j as usize) < src.len()),
        "gather: index out of bounds"
    );
    // SAFETY: this table is only selectable after avx2+fma detection
    // alongside avx512f (see the dispatcher); indices verified in
    // bounds above.
    unsafe { gather_i32(src, idx, out) }
}

/// Horizontal sum of a 512-bit vector. One fixed, per-process
/// deterministic reduction shared by every kernel in this table — that
/// sharing is what keeps blocked ≡ single-row bit-identical.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn hsum512(v: __m512) -> f32 {
    _mm512_reduce_add_ps(v)
}

/// Single-row dot over raw pointers; the canonical accumulation order
/// every blocked kernel replicates per row.
#[target_feature(enable = "avx512f")]
unsafe fn dot_512(pa: *const f32, pb: *const f32, n: usize) -> f32 {
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        acc0 = _mm512_fmadd_ps(
            _mm512_loadu_ps(pa.add(i)),
            _mm512_loadu_ps(pb.add(i)),
            acc0,
        );
        acc1 = _mm512_fmadd_ps(
            _mm512_loadu_ps(pa.add(i + 16)),
            _mm512_loadu_ps(pb.add(i + 16)),
            acc1,
        );
        i += 32;
    }
    if i + 16 <= n {
        acc0 = _mm512_fmadd_ps(
            _mm512_loadu_ps(pa.add(i)),
            _mm512_loadu_ps(pb.add(i)),
            acc0,
        );
        i += 16;
    }
    let mut sum = hsum512(_mm512_add_ps(acc0, acc1));
    while i < n {
        sum += *pa.add(i) * *pb.add(i);
        i += 1;
    }
    sum
}

/// Eight rows dotted against one query, sharing every query register
/// load. Per-row accumulation is exactly [`dot_512`]'s order.
#[target_feature(enable = "avx512f")]
unsafe fn dot8_512(ps: &[*const f32; 8], pq: *const f32, n: usize) -> [f32; 8] {
    let mut a0 = [_mm512_setzero_ps(); 8];
    let mut a1 = [_mm512_setzero_ps(); 8];
    let mut i = 0usize;
    while i + 32 <= n {
        let q0 = _mm512_loadu_ps(pq.add(i));
        let q1 = _mm512_loadu_ps(pq.add(i + 16));
        for r in 0..8 {
            a0[r] = _mm512_fmadd_ps(_mm512_loadu_ps(ps[r].add(i)), q0, a0[r]);
            a1[r] = _mm512_fmadd_ps(_mm512_loadu_ps(ps[r].add(i + 16)), q1, a1[r]);
        }
        i += 32;
    }
    if i + 16 <= n {
        let q0 = _mm512_loadu_ps(pq.add(i));
        for r in 0..8 {
            a0[r] = _mm512_fmadd_ps(_mm512_loadu_ps(ps[r].add(i)), q0, a0[r]);
        }
        i += 16;
    }
    let mut s = [0f32; 8];
    for r in 0..8 {
        s[r] = hsum512(_mm512_add_ps(a0[r], a1[r]));
    }
    while i < n {
        let qv = *pq.add(i);
        for r in 0..8 {
            s[r] += *ps[r].add(i) * qv;
        }
        i += 1;
    }
    s
}

#[target_feature(enable = "avx512f")]
unsafe fn dot_rows_512(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    let rows = out.len();
    let pq = q.as_ptr();
    let base = block.as_ptr();
    let mut r = 0usize;
    while r + 8 <= rows {
        let p0 = base.add(r * dim);
        let ps = [
            p0,
            p0.add(dim),
            p0.add(2 * dim),
            p0.add(3 * dim),
            p0.add(4 * dim),
            p0.add(5 * dim),
            p0.add(6 * dim),
            p0.add(7 * dim),
        ];
        let s = dot8_512(&ps, pq, dim);
        out[r..r + 8].copy_from_slice(&s);
        r += 8;
    }
    while r < rows {
        out[r] = dot_512(base.add(r * dim), pq, dim);
        r += 1;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn partial_dot_rows_512(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    let n = q.len();
    let pq = q.as_ptr();
    let mut r = 0usize;
    while r + 8 <= rows.len() {
        debug_assert!(rows[r..r + 8].iter().all(|row| row.len() == n));
        let ps = [
            rows[r].as_ptr(),
            rows[r + 1].as_ptr(),
            rows[r + 2].as_ptr(),
            rows[r + 3].as_ptr(),
            rows[r + 4].as_ptr(),
            rows[r + 5].as_ptr(),
            rows[r + 6].as_ptr(),
            rows[r + 7].as_ptr(),
        ];
        let s = dot8_512(&ps, pq, n);
        out[r..r + 8].copy_from_slice(&s);
        r += 8;
    }
    while r < rows.len() {
        debug_assert_eq!(rows[r].len(), n);
        out[r] = dot_512(rows[r].as_ptr(), pq, n);
        r += 1;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn axpy_512(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let va = _mm512_set1_ps(alpha);
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    let mut i = 0usize;
    while i + 16 <= n {
        let yv = _mm512_loadu_ps(py.add(i));
        let xv = _mm512_loadu_ps(px.add(i));
        _mm512_storeu_ps(py.add(i), _mm512_fmadd_ps(va, xv, yv));
        i += 16;
    }
    while i < n {
        *py.add(i) += alpha * *px.add(i);
        i += 1;
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn dist_sq_512(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc0 = _mm512_setzero_ps();
    let mut acc1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        let d0 = _mm512_sub_ps(_mm512_loadu_ps(pa.add(i)), _mm512_loadu_ps(pb.add(i)));
        let d1 = _mm512_sub_ps(
            _mm512_loadu_ps(pa.add(i + 16)),
            _mm512_loadu_ps(pb.add(i + 16)),
        );
        acc0 = _mm512_fmadd_ps(d0, d0, acc0);
        acc1 = _mm512_fmadd_ps(d1, d1, acc1);
        i += 32;
    }
    if i + 16 <= n {
        let d0 = _mm512_sub_ps(_mm512_loadu_ps(pa.add(i)), _mm512_loadu_ps(pb.add(i)));
        acc0 = _mm512_fmadd_ps(d0, d0, acc0);
        i += 16;
    }
    let mut sum = hsum512(_mm512_add_ps(acc0, acc1));
    while i < n {
        let d = *pa.add(i) - *pb.add(i);
        sum += d * d;
        i += 1;
    }
    sum
}

/// Hardware index gather, 8 lanes per `vgatherdps` (the 256-bit form —
/// universally present alongside avx512f), scalar remainder.
#[target_feature(enable = "avx2")]
unsafe fn gather_i32(src: &[f32], idx: &[u32], out: &mut [f32]) {
    let n = idx.len();
    let base = src.as_ptr();
    let pi = idx.as_ptr();
    let po = out.as_mut_ptr();
    let mut t = 0usize;
    while t + 8 <= n {
        let vi = _mm256_loadu_si256(pi.add(t) as *const __m256i);
        _mm256_storeu_ps(po.add(t), _mm256_i32gather_ps::<4>(base, vi));
        t += 8;
    }
    while t < n {
        *po.add(t) = *base.add(*pi.add(t) as usize);
        t += 1;
    }
}
