//! Widening kernels for the compressed dataset tiers (`f16`, `bf16`,
//! `int8`): the hardware floor of mixed-precision scoring.
//!
//! Every hot path in the repo is memory-bandwidth-bound, so halving or
//! quartering bytes-per-coordinate is the biggest raw-speed lever left
//! (see the `fused_scan_*` / `pull_panel_*` rows of the `hotpath`
//! bench). This module mirrors the parent module's design one axis
//! over: per compressed element type there is a [`WideKernels`] table
//! of plain `fn` pointers — `dot`, `dot_rows`, `partial_dot_rows`,
//! `gather` — selected **once per process** per format and cached in a
//! [`OnceLock`], honoring the same `RUST_PALLAS_FORCE_SCALAR` escape
//! hatch as the f32 tables. Kernels *load compressed, widen in
//! registers, accumulate in f32* — the dataset stays 2 or 4 bytes per
//! coordinate in memory and only becomes f32 inside the FMA loop.
//!
//! # Formats
//!
//! * **f16** (IEEE 754 binary16, stored as `u16`): exact 8/16-lane
//!   hardware widening via F16C `vcvtph2ps` on x86 (`f16c` detected)
//!   and the AVX-512F form on `avx512f` machines. Decode is *exact*
//!   (every f16 is representable in f32), so scalar and hardware
//!   widening produce identical element values.
//! * **bf16** (truncated f32, stored as `u16`): widening is a zero-cost
//!   integer shift (`u32 << 16`), done 8/16-lanes at a time on x86 and
//!   4-lanes on NEON. Exact decode, same agreement story as f16.
//! * **int8** (per-row-scaled codes, stored as `i8`): kernels compute
//!   the **raw unscaled** code·query sum (`i8 → f32` conversion is
//!   exact); the caller multiplies by the row's scale. Keeping the
//!   scale outside the kernel keeps the table shape uniform and lets
//!   the panel paths carry one scale per survivor row.
//!
//! # Contracts (mirroring the parent module)
//!
//! 1. Within one table, `dot_rows` / `partial_dot_rows` ≡ `dot` per row
//!    **bit for bit** (the blocked kernels are per-row loops over the
//!    table's own `dot`; row-blocking with shared query registers is a
//!    recorded follow-on).
//! 2. The scalar wide `dot` replicates the f32 scalar backend's
//!    16-lane pairwise accumulation structure exactly, so for the
//!    exact-decode formats (f16/bf16) `scalar_wide(dot)(codes, q)` is
//!    bit-identical to `scalar(dot)(decode(codes), q)`.
//! 3. Cross-table agreement is the parent module's ~1e-4 relative
//!    tolerance (different accumulation orders).
//! 4. `gather` is pure element movement (no widening) and exact on
//!    every backend.
//!
//! # Capability reporting
//!
//! ISA labels distinguish *hardware-backed* widening from
//! *scalar-widened* fallbacks: `"f16c"` / `"avx512"` for hardware f16,
//! `"avx2-widen"` / `"avx512-widen"` / `"neon-widen"` for integer-path
//! widening, `"scalar"` otherwise. On aarch64 the f16 table is the
//! scalar one — Rust's native NEON fp16 intrinsics are not yet stable
//! (recorded follow-on); bf16/int8 get real NEON kernels.
//! [`format_isas`] summarizes all four formats for benches and the
//! agreement batteries.

use super::force_scalar_requested;
use std::sync::OnceLock;

/// Accumulator width of the scalar wide kernels — must equal the f32
/// scalar backend's lane count so contract 2 (module docs) holds.
const LANES: usize = 16;

// ---------------------------------------------------------------------------
// Element conversions (exact decodes; round-to-nearest-even encodes)
// ---------------------------------------------------------------------------

/// Decode one IEEE binary16 value to f32. Exact for every input,
/// including subnormals, infinities, and NaN (payload preserved in the
/// top 10 bits, quiet bit kept).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1f;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: value = mant·2⁻²⁴ = 1.x·2^(p−24) where p is the
            // mantissa's MSB position; f32 exponent field = p + 103.
            let p = 31 - mant.leading_zeros();
            sign | ((p + 103) << 23) | ((mant << (23 - p)) & 0x007f_ffff)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else {
        sign | ((exp as u32 + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Encode an f32 to IEEE binary16 with round-to-nearest-even, the
/// rounding F16C `vcvtps2ph` performs. Overflow saturates to infinity;
/// NaN stays NaN (quiet bit forced).
#[inline]
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let mant = bits & 0x007f_ffff;
    if exp == 128 {
        // Inf or NaN; 0x200 keeps NaN-ness even when the payload's top
        // 10 bits are zero.
        return if mant == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7c00 | 0x0200 | ((mant >> 13) as u16 & 0x03ff)
        };
    }
    if exp >= 16 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if exp >= -14 {
        // Normal half: drop 13 mantissa bits with RNE; a mantissa carry
        // correctly bumps the exponent (up to inf).
        let base = (((exp + 15) as u32) << 10) | (mant >> 13);
        let round = (mant >> 12) & 1;
        let sticky = (mant & 0x0fff) != 0;
        let lsb = (mant >> 13) & 1;
        let inc = (round == 1 && (sticky || lsb == 1)) as u32;
        return sign | (base + inc) as u16;
    }
    if exp >= -25 {
        // Subnormal half: m_h = (2²³+mant)·2^(exp+1), RNE on the shift.
        let m = mant | 0x0080_0000;
        let shift = (-exp - 1) as u32; // 14..=24
        let base = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let inc = (rem > half || (rem == half && (base & 1) == 1)) as u32;
        // A carry out of base = 0x3ff lands on 0x400 — exactly the
        // smallest normal half, which is the correct rounding.
        return sign | (base + inc) as u16;
    }
    sign // underflow → signed zero
}

/// Decode one bfloat16 value to f32: the stored bits are the f32's top
/// 16 bits. Exact by construction.
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Encode an f32 to bfloat16 with round-to-nearest-even (truncate the
/// low 16 bits after adding the RNE bias). NaN keeps a nonzero
/// mantissa.
#[inline]
pub fn bf16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    (bits.wrapping_add(0x7fff + ((bits >> 16) & 1)) >> 16) as u16
}

/// Decode one int8 code to f32 (exact: every i8 is representable).
/// The per-row scale is applied by the caller, not here.
#[inline]
pub fn i8_to_f32(c: i8) -> f32 {
    c as f32
}

// ---------------------------------------------------------------------------
// Kernel table
// ---------------------------------------------------------------------------

/// One ISA's widening kernel set over compressed element type `E`
/// (`u16` for f16/bf16 — separate tables per format — `i8` for int8).
/// Same plain-`fn`-pointer design as the parent module's
/// [`super::KernelTable`]; for int8 the dot kernels return the **raw**
/// code·query sum (caller applies the per-row scale).
pub struct WideKernels<E: 'static> {
    /// Capability label: `"scalar"`, `"f16c"`, `"avx2-widen"`,
    /// `"avx512"`, `"avx512-widen"`, `"neon-widen"`. Anything other
    /// than `"scalar"` means the widening loads are hardware-backed.
    pub isa: &'static str,
    /// Widening dot product: `Σ decode(a[j])·q[j]` (raw codes for int8).
    pub dot: fn(&[E], &[f32]) -> f32,
    /// Blocked row scoring over a compressed row-major block; per-row
    /// accumulation is exactly this table's `dot`.
    pub dot_rows: fn(&[E], usize, &[f32], &mut [f32]),
    /// Scattered blocked scoring over pre-sliced compressed row windows.
    pub partial_dot_rows: fn(&[&[E]], &[f32], &mut [f32]),
    /// Index gather `out[t] = src[idx[t]]` over compressed elements —
    /// pure data movement (query-order gathers, panel compaction).
    pub gather: fn(&[E], &[u32], &mut [E]),
}

// Manual impls: `derive` would put an unwanted `E: Clone/Copy` bound on
// the element type parameter of a struct that only stores fn pointers.
impl<E: 'static> Clone for WideKernels<E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E: 'static> Copy for WideKernels<E> {}

// ---------------------------------------------------------------------------
// Scalar backends (always available; the reference for the batteries)
// ---------------------------------------------------------------------------

/// Scalar widening dot: byte-for-byte the f32 scalar backend's 16-lane
/// pairwise structure with a per-element decode — so for exact decodes
/// the result is bit-identical to decoding first and running the f32
/// scalar `dot` (contract 2 of the module docs).
#[inline(always)]
fn dot_coded<E: Copy>(a: &[E], b: &[f32], dec: impl Fn(E) -> f32) -> f32 {
    let mut acc = [0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..LANES {
            acc[i] += dec(xa[i]) * xb[i];
        }
    }
    let mut tail = 0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += dec(*x) * y;
    }
    let mut width = LANES / 2;
    while width > 0 {
        for i in 0..width {
            acc[i] += acc[i + width];
        }
        width /= 2;
    }
    acc[0] + tail
}

/// Generates the safe blocked kernels (per-row loops over `$dot`, which
/// makes blocked ≡ single-row bit-identity trivial) for one table.
macro_rules! blocked_from_dot {
    ($elem:ty, $dot:path, $dot_rows:ident, $partial:ident) => {
        fn $dot_rows(block: &[$elem], dim: usize, q: &[f32], out: &mut [f32]) {
            assert_eq!(block.len(), out.len() * dim, "dot_rows: block/out shape mismatch");
            assert_eq!(q.len(), dim, "dot_rows: query dim mismatch");
            for (i, o) in out.iter_mut().enumerate() {
                *o = $dot(&block[i * dim..(i + 1) * dim], q);
            }
        }
        fn $partial(rows: &[&[$elem]], q: &[f32], out: &mut [f32]) {
            assert_eq!(rows.len(), out.len(), "partial_dot_rows: rows/out mismatch");
            assert!(
                rows.iter().all(|r| r.len() == q.len()),
                "partial_dot_rows: row/query length mismatch"
            );
            for (r, o) in rows.iter().zip(out.iter_mut()) {
                *o = $dot(r, q);
            }
        }
    };
}

/// Element gather shared by every table of an element type: compressed
/// elements are sub-word, so the scalar move loop is already optimal
/// (x86's `vgatherdps` only gathers 32-bit lanes). Hard asserts mirror
/// the f32 backends.
#[inline(always)]
fn gather_elem<E: Copy>(src: &[E], idx: &[u32], out: &mut [E]) {
    assert_eq!(idx.len(), out.len(), "gather: idx/out length mismatch");
    assert!(
        idx.iter().all(|&j| (j as usize) < src.len()),
        "gather: index out of bounds"
    );
    for (o, &j) in out.iter_mut().zip(idx) {
        *o = src[j as usize];
    }
}

fn gather_u16(src: &[u16], idx: &[u32], out: &mut [u16]) {
    gather_elem(src, idx, out);
}

fn gather_i8(src: &[i8], idx: &[u32], out: &mut [i8]) {
    gather_elem(src, idx, out);
}

fn dot_f16_scalar(a: &[u16], q: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    dot_coded(a, q, f16_to_f32)
}

fn dot_bf16_scalar(a: &[u16], q: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    dot_coded(a, q, bf16_to_f32)
}

fn dot_i8_scalar(a: &[i8], q: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), q.len());
    dot_coded(a, q, i8_to_f32)
}

blocked_from_dot!(u16, dot_f16_scalar, dot_rows_f16_scalar, partial_f16_scalar);
blocked_from_dot!(u16, dot_bf16_scalar, dot_rows_bf16_scalar, partial_bf16_scalar);
blocked_from_dot!(i8, dot_i8_scalar, dot_rows_i8_scalar, partial_i8_scalar);

static F16_SCALAR: WideKernels<u16> = WideKernels {
    isa: "scalar",
    dot: dot_f16_scalar,
    dot_rows: dot_rows_f16_scalar,
    partial_dot_rows: partial_f16_scalar,
    gather: gather_u16,
};

static BF16_SCALAR: WideKernels<u16> = WideKernels {
    isa: "scalar",
    dot: dot_bf16_scalar,
    dot_rows: dot_rows_bf16_scalar,
    partial_dot_rows: partial_bf16_scalar,
    gather: gather_u16,
};

static INT8_SCALAR: WideKernels<i8> = WideKernels {
    isa: "scalar",
    dot: dot_i8_scalar,
    dot_rows: dot_rows_i8_scalar,
    partial_dot_rows: partial_i8_scalar,
    gather: gather_i8,
};

// ---------------------------------------------------------------------------
// x86-64 backends: F16C / integer-widening loads feeding 256/512-bit FMA
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{
        bf16_to_f32, f16_to_f32, gather_i8, gather_u16, i8_to_f32, WideKernels,
    };
    use core::arch::x86_64::*;

    /// Horizontal sum of a 256-bit vector — the exact reduction ladder
    /// of the parent module's AVX2 backend (fold halves, then
    /// movehdup/movehl).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let shuf = _mm_movehdup_ps(s);
        let sums = _mm_add_ps(s, shuf);
        let shuf2 = _mm_movehl_ps(shuf, sums);
        _mm_cvtss_f32(_mm_add_ss(sums, shuf2))
    }

    // ---- 256-bit widening loads (8 elements each) ----

    #[inline]
    #[target_feature(enable = "avx2", enable = "f16c")]
    unsafe fn widen_f16_256(p: *const u16) -> __m256 {
        _mm256_cvtph_ps(_mm_loadu_si128(p as *const __m128i))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen_bf16_256(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen_i8_256(p: *const i8) -> __m256 {
        let b = _mm_loadl_epi64(p as *const __m128i);
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(b))
    }

    /// Generates one 256-bit widening dot (the AVX2 f32 backend's
    /// accumulation order: two 8-lane FMA accumulators over 16-element
    /// chunks, optional 8-chunk into acc0, `hsum256(acc0+acc1)`, then a
    /// software-decoded scalar tail) plus its safe table entries.
    macro_rules! wide_dot_256 {
        ([$($feat:literal),+], $elem:ty, $widen:ident, $dec:path,
         $kern:ident, $dot:ident, $dot_rows:ident, $partial:ident) => {
            #[target_feature($(enable = $feat),+)]
            unsafe fn $kern(pa: *const $elem, pb: *const f32, n: usize) -> f32 {
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                let mut i = 0usize;
                while i + 16 <= n {
                    acc0 = _mm256_fmadd_ps($widen(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
                    acc1 = _mm256_fmadd_ps(
                        $widen(pa.add(i + 8)),
                        _mm256_loadu_ps(pb.add(i + 8)),
                        acc1,
                    );
                    i += 16;
                }
                if i + 8 <= n {
                    acc0 = _mm256_fmadd_ps($widen(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
                    i += 8;
                }
                let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
                while i < n {
                    sum += $dec(*pa.add(i)) * *pb.add(i);
                    i += 1;
                }
                sum
            }

            fn $dot(a: &[$elem], q: &[f32]) -> f32 {
                debug_assert_eq!(a.len(), q.len());
                // min() mirrors the f32 backends' zip-truncation
                // semantics on a release-mode length mismatch.
                let n = a.len().min(q.len());
                // SAFETY: this table is only selectable after runtime
                // detection of avx2+fma (+ the format feature); n is
                // within both slices.
                unsafe { $kern(a.as_ptr(), q.as_ptr(), n) }
            }

            blocked_from_dot!($elem, $dot, $dot_rows, $partial);
        };
    }

    /// Generates one 512-bit widening dot (the AVX-512 f32 backend's
    /// accumulation order: two 16-lane FMA accumulators over 32-element
    /// chunks, optional 16-chunk into acc0, `_mm512_reduce_add_ps`,
    /// then a software-decoded scalar tail) plus its safe entries.
    macro_rules! wide_dot_512 {
        ($elem:ty, $widen:ident, $dec:path,
         $kern:ident, $dot:ident, $dot_rows:ident, $partial:ident) => {
            #[target_feature(enable = "avx512f")]
            unsafe fn $kern(pa: *const $elem, pb: *const f32, n: usize) -> f32 {
                let mut acc0 = _mm512_setzero_ps();
                let mut acc1 = _mm512_setzero_ps();
                let mut i = 0usize;
                while i + 32 <= n {
                    acc0 = _mm512_fmadd_ps($widen(pa.add(i)), _mm512_loadu_ps(pb.add(i)), acc0);
                    acc1 = _mm512_fmadd_ps(
                        $widen(pa.add(i + 16)),
                        _mm512_loadu_ps(pb.add(i + 16)),
                        acc1,
                    );
                    i += 32;
                }
                if i + 16 <= n {
                    acc0 = _mm512_fmadd_ps($widen(pa.add(i)), _mm512_loadu_ps(pb.add(i)), acc0);
                    i += 16;
                }
                let mut sum = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
                while i < n {
                    sum += $dec(*pa.add(i)) * *pb.add(i);
                    i += 1;
                }
                sum
            }

            fn $dot(a: &[$elem], q: &[f32]) -> f32 {
                debug_assert_eq!(a.len(), q.len());
                let n = a.len().min(q.len());
                // SAFETY: table selectable only after avx512f (+ format
                // feature) detection; n is within both slices.
                unsafe { $kern(a.as_ptr(), q.as_ptr(), n) }
            }

            blocked_from_dot!($elem, $dot, $dot_rows, $partial);
        };
    }

    // ---- 512-bit widening loads (16 elements each) ----

    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn widen_f16_512(p: *const u16) -> __m512 {
        _mm512_cvtph_ps(_mm256_loadu_si256(p as *const __m256i))
    }

    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn widen_bf16_512(p: *const u16) -> __m512 {
        let h = _mm256_loadu_si256(p as *const __m256i);
        _mm512_castsi512_ps(_mm512_slli_epi32::<16>(_mm512_cvtepu16_epi32(h)))
    }

    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn widen_i8_512(p: *const i8) -> __m512 {
        let b = _mm_loadu_si128(p as *const __m128i);
        _mm512_cvtepi32_ps(_mm512_cvtepi8_epi32(b))
    }

    wide_dot_256!(["avx2", "fma", "f16c"], u16, widen_f16_256, f16_to_f32,
        dot_f16_kern, dot_f16, dot_rows_f16, partial_f16);
    wide_dot_256!(["avx2", "fma"], u16, widen_bf16_256, bf16_to_f32,
        dot_bf16_kern, dot_bf16, dot_rows_bf16, partial_bf16);
    wide_dot_256!(["avx2", "fma"], i8, widen_i8_256, i8_to_f32,
        dot_i8_kern, dot_i8, dot_rows_i8, partial_i8);

    wide_dot_512!(u16, widen_f16_512, f16_to_f32,
        dot_f16_kern512, dot_f16_512, dot_rows_f16_512, partial_f16_512);
    wide_dot_512!(u16, widen_bf16_512, bf16_to_f32,
        dot_bf16_kern512, dot_bf16_512, dot_rows_bf16_512, partial_bf16_512);
    wide_dot_512!(i8, widen_i8_512, i8_to_f32,
        dot_i8_kern512, dot_i8_512, dot_rows_i8_512, partial_i8_512);

    pub(super) static F16_AVX2: WideKernels<u16> = WideKernels {
        isa: "f16c",
        dot: dot_f16,
        dot_rows: dot_rows_f16,
        partial_dot_rows: partial_f16,
        gather: gather_u16,
    };

    pub(super) static BF16_AVX2: WideKernels<u16> = WideKernels {
        isa: "avx2-widen",
        dot: dot_bf16,
        dot_rows: dot_rows_bf16,
        partial_dot_rows: partial_bf16,
        gather: gather_u16,
    };

    pub(super) static INT8_AVX2: WideKernels<i8> = WideKernels {
        isa: "avx2-widen",
        dot: dot_i8,
        dot_rows: dot_rows_i8,
        partial_dot_rows: partial_i8,
        gather: gather_i8,
    };

    pub(super) static F16_AVX512: WideKernels<u16> = WideKernels {
        isa: "avx512",
        dot: dot_f16_512,
        dot_rows: dot_rows_f16_512,
        partial_dot_rows: partial_f16_512,
        gather: gather_u16,
    };

    pub(super) static BF16_AVX512: WideKernels<u16> = WideKernels {
        isa: "avx512-widen",
        dot: dot_bf16_512,
        dot_rows: dot_rows_bf16_512,
        partial_dot_rows: partial_bf16_512,
        gather: gather_u16,
    };

    pub(super) static INT8_AVX512: WideKernels<i8> = WideKernels {
        isa: "avx512-widen",
        dot: dot_i8_512,
        dot_rows: dot_rows_i8_512,
        partial_dot_rows: partial_i8_512,
        gather: gather_i8,
    };
}

// ---------------------------------------------------------------------------
// aarch64 backends: integer-widening NEON for bf16 / int8
// (native NEON fp16 FMA is a recorded follow-on — the intrinsics are
// not yet stable — so the f16 table on aarch64 is the scalar one)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon_wide {
    use super::{bf16_to_f32, gather_i8, gather_u16, i8_to_f32, WideKernels};
    use core::arch::aarch64::*;

    /// bf16 → f32 widen, 4 lanes: zero-extend u16 → u32 and shift into
    /// the mantissa-aligned position (exact, purely integer).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn widen_bf16_4(p: *const u16) -> float32x4_t {
        vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vld1_u16(p))))
    }

    /// int8 → f32 widen, 8 lanes in two quads (exact conversions).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn widen_i8_8(p: *const i8) -> (float32x4_t, float32x4_t) {
        let w = vmovl_s8(vld1_s8(p));
        (
            vcvtq_f32_s32(vmovl_s16(vget_low_s16(w))),
            vcvtq_f32_s32(vmovl_s16(vget_high_s16(w))),
        )
    }

    /// NEON bf16 dot in the f32 NEON backend's accumulation order: four
    /// 4-lane FMA accumulators over 16-element chunks, a 4-element
    /// cleanup loop into acc0, the fixed vaddvq ladder, scalar tail.
    #[target_feature(enable = "neon")]
    unsafe fn dot_bf16_kern(pa: *const u16, pb: *const f32, n: usize) -> f32 {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = vfmaq_f32(acc0, widen_bf16_4(pa.add(i)), vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, widen_bf16_4(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            acc2 = vfmaq_f32(acc2, widen_bf16_4(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
            acc3 = vfmaq_f32(acc3, widen_bf16_4(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
            i += 16;
        }
        while i + 4 <= n {
            acc0 = vfmaq_f32(acc0, widen_bf16_4(pa.add(i)), vld1q_f32(pb.add(i)));
            i += 4;
        }
        let mut sum = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
        while i < n {
            sum += bf16_to_f32(*pa.add(i)) * *pb.add(i);
            i += 1;
        }
        sum
    }

    /// NEON int8 dot (raw code sums): two 8-lane widens per 16-element
    /// chunk feeding the same four accumulators, then the 8-element
    /// cleanup, vaddvq ladder, and scalar tail.
    #[target_feature(enable = "neon")]
    unsafe fn dot_i8_kern(pa: *const i8, pb: *const f32, n: usize) -> f32 {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 16 <= n {
            let (w0, w1) = widen_i8_8(pa.add(i));
            let (w2, w3) = widen_i8_8(pa.add(i + 8));
            acc0 = vfmaq_f32(acc0, w0, vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, w1, vld1q_f32(pb.add(i + 4)));
            acc2 = vfmaq_f32(acc2, w2, vld1q_f32(pb.add(i + 8)));
            acc3 = vfmaq_f32(acc3, w3, vld1q_f32(pb.add(i + 12)));
            i += 16;
        }
        while i + 8 <= n {
            let (w0, w1) = widen_i8_8(pa.add(i));
            acc0 = vfmaq_f32(acc0, w0, vld1q_f32(pb.add(i)));
            acc1 = vfmaq_f32(acc1, w1, vld1q_f32(pb.add(i + 4)));
            i += 8;
        }
        let mut sum = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
        while i < n {
            sum += i8_to_f32(*pa.add(i)) * *pb.add(i);
            i += 1;
        }
        sum
    }

    fn dot_bf16(a: &[u16], q: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), q.len());
        let n = a.len().min(q.len());
        // SAFETY: NEON is mandatory on aarch64; n is within both slices.
        unsafe { dot_bf16_kern(a.as_ptr(), q.as_ptr(), n) }
    }

    fn dot_i8(a: &[i8], q: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), q.len());
        let n = a.len().min(q.len());
        // SAFETY: as above.
        unsafe { dot_i8_kern(a.as_ptr(), q.as_ptr(), n) }
    }

    blocked_from_dot!(u16, dot_bf16, dot_rows_bf16, partial_bf16);
    blocked_from_dot!(i8, dot_i8, dot_rows_i8, partial_i8);

    pub(super) static BF16_NEON: WideKernels<u16> = WideKernels {
        isa: "neon-widen",
        dot: dot_bf16,
        dot_rows: dot_rows_bf16,
        partial_dot_rows: partial_bf16,
        gather: gather_u16,
    };

    pub(super) static INT8_NEON: WideKernels<i8> = WideKernels {
        isa: "neon-widen",
        dot: dot_i8,
        dot_rows: dot_rows_i8,
        partial_dot_rows: partial_i8,
        gather: gather_i8,
    };
}

// ---------------------------------------------------------------------------
// Per-format dispatch and capability listing
// ---------------------------------------------------------------------------

#[allow(unreachable_code)] // the aarch64 arms return unconditionally
fn detect_f16() -> &'static WideKernels<u16> {
    #[cfg(target_arch = "x86_64")]
    {
        // The wide tables share the parent module's AVX2+FMA floor (and
        // f16c for hardware vcvtph2ps); the 512-bit upgrade additionally
        // needs avx512f.
        if std::arch::is_x86_feature_detected!("f16c")
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return &x86::F16_AVX512;
            }
            return &x86::F16_AVX2;
        }
    }
    // aarch64: native NEON fp16 widening is a recorded follow-on (the
    // intrinsics are unstable), so f16 decodes in scalar there.
    &F16_SCALAR
}

#[allow(unreachable_code)]
fn detect_bf16() -> &'static WideKernels<u16> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return &x86::BF16_AVX512;
            }
            return &x86::BF16_AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return &neon_wide::BF16_NEON;
    }
    &BF16_SCALAR
}

#[allow(unreachable_code)]
fn detect_int8() -> &'static WideKernels<i8> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return &x86::INT8_AVX512;
            }
            return &x86::INT8_AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return &neon_wide::INT8_NEON;
    }
    &INT8_SCALAR
}

static F16_ACTIVE: OnceLock<&'static WideKernels<u16>> = OnceLock::new();
static BF16_ACTIVE: OnceLock<&'static WideKernels<u16>> = OnceLock::new();
static INT8_ACTIVE: OnceLock<&'static WideKernels<i8>> = OnceLock::new();

/// The process-wide dispatched f16 widening table (honors
/// `RUST_PALLAS_FORCE_SCALAR` exactly like [`super::kernels`]).
#[inline]
pub fn f16_kernels() -> &'static WideKernels<u16> {
    *F16_ACTIVE.get_or_init(|| {
        if force_scalar_requested() {
            &F16_SCALAR
        } else {
            detect_f16()
        }
    })
}

/// The process-wide dispatched bf16 widening table.
#[inline]
pub fn bf16_kernels() -> &'static WideKernels<u16> {
    *BF16_ACTIVE.get_or_init(|| {
        if force_scalar_requested() {
            &BF16_SCALAR
        } else {
            detect_bf16()
        }
    })
}

/// The process-wide dispatched int8 widening table (raw code sums; the
/// caller applies per-row scales).
#[inline]
pub fn int8_kernels() -> &'static WideKernels<i8> {
    *INT8_ACTIVE.get_or_init(|| {
        if force_scalar_requested() {
            &INT8_SCALAR
        } else {
            detect_int8()
        }
    })
}

/// The always-available scalar f16 table (the reference the agreement
/// batteries compare against).
pub fn f16_scalar_kernels() -> &'static WideKernels<u16> {
    &F16_SCALAR
}

/// The always-available scalar bf16 table.
pub fn bf16_scalar_kernels() -> &'static WideKernels<u16> {
    &BF16_SCALAR
}

/// The always-available scalar int8 table.
pub fn int8_scalar_kernels() -> &'static WideKernels<i8> {
    &INT8_SCALAR
}

/// Every f16 table runnable on this machine right now (scalar always,
/// plus each detected hardware-widening table), independent of the
/// process-wide dispatch pin — the property tests iterate this.
pub fn available_f16_tables() -> Vec<&'static WideKernels<u16>> {
    #[allow(unused_mut)]
    let mut tables: Vec<&'static WideKernels<u16>> = vec![&F16_SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("f16c")
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            tables.push(&x86::F16_AVX2);
            if std::arch::is_x86_feature_detected!("avx512f") {
                tables.push(&x86::F16_AVX512);
            }
        }
    }
    tables
}

/// Every bf16 table runnable on this machine right now.
pub fn available_bf16_tables() -> Vec<&'static WideKernels<u16>> {
    #[allow(unused_mut)]
    let mut tables: Vec<&'static WideKernels<u16>> = vec![&BF16_SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            tables.push(&x86::BF16_AVX2);
            if std::arch::is_x86_feature_detected!("avx512f") {
                tables.push(&x86::BF16_AVX512);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        tables.push(&neon_wide::BF16_NEON);
    }
    tables
}

/// Every int8 table runnable on this machine right now.
pub fn available_int8_tables() -> Vec<&'static WideKernels<i8>> {
    #[allow(unused_mut)]
    let mut tables: Vec<&'static WideKernels<i8>> = vec![&INT8_SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            tables.push(&x86::INT8_AVX2);
            if std::arch::is_x86_feature_detected!("avx512f") {
                tables.push(&x86::INT8_AVX512);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        tables.push(&neon_wide::INT8_NEON);
    }
    tables
}

/// Per-format capability summary of the *dispatched* tables:
/// `[("f32", ...), ("f16", ...), ("bf16", ...), ("int8", ...)]`. Labels
/// other than `"scalar"` mean the format's widening loads are
/// hardware-backed on this machine (see the module docs); benches emit
/// this next to `bytes_per_coord` so trajectory rows are
/// self-describing.
pub fn format_isas() -> Vec<(&'static str, &'static str)> {
    vec![
        ("f32", super::kernels().isa),
        ("f16", f16_kernels().isa),
        ("bf16", bf16_kernels().isa),
        ("int8", int8_kernels().isa),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trip_exact_on_representables() {
        // Every finite f16 bit pattern decodes to an f32 that encodes
        // back to the same bits (RNE is exact on exact values).
        for h in 0..=0xffffu32 {
            let h = h as u16;
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/NaN handled below
            }
            let x = f16_to_f32(h);
            assert_eq!(f16_from_f32(x), h, "bits {h:#06x} → {x} did not round-trip");
        }
    }

    #[test]
    fn f16_decode_known_values() {
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0xbc00), -1.0);
        assert_eq!(f16_to_f32(0x4000), 2.0);
        assert_eq!(f16_to_f32(0x3555), 0.333_251_95); // nearest f16 to 1/3
        assert_eq!(f16_to_f32(0x0001), 5.960_464_5e-8); // smallest subnormal
        assert_eq!(f16_to_f32(0x0400), 6.103_515_6e-5); // smallest normal
        assert_eq!(f16_to_f32(0x7bff), 65504.0); // largest finite
        assert!(f16_to_f32(0x7c00).is_infinite());
        assert!(f16_to_f32(0x7c01).is_nan());
        assert_eq!(f16_to_f32(0x8000).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_encode_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 and the next f16; RNE → 1.0.
        assert_eq!(f16_from_f32(1.0 + 2f32.powi(-11)), 0x3c00);
        // 1 + 3·2^-11 sits between 1+2^-10 and 1+2^-9; RNE → even (0x3c02).
        assert_eq!(f16_from_f32(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Overflow saturates to inf, underflow to signed zero.
        assert_eq!(f16_from_f32(1e6), 0x7c00);
        assert_eq!(f16_from_f32(-1e6), 0xfc00);
        assert_eq!(f16_from_f32(1e-10), 0x0000);
        assert_eq!(f16_from_f32(-1e-10), 0x8000);
        assert!(f16_to_f32(f16_from_f32(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_round_trip_and_rounding() {
        for x in [0.0f32, 1.0, -1.0, 0.5, 65504.0, 3.0e38, 1.0e-38] {
            let back = bf16_to_f32(bf16_from_f32(x));
            let err = (back - x).abs();
            // bf16 has 8 mantissa bits: relative error ≤ 2^-8.
            assert!(err <= x.abs() * 0.00391 + f32::MIN_POSITIVE, "{x} → {back}");
        }
        // Values whose low 16 bits are zero are exact.
        assert_eq!(bf16_to_f32(bf16_from_f32(1.5)), 1.5);
        assert_eq!(bf16_to_f32(bf16_from_f32(-2.0)), -2.0);
        assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
    }

    #[test]
    fn scalar_wide_dot_matches_decoded_f32_dot_bitwise() {
        // Contract 2: the scalar wide dot on codes ≡ the f32 scalar dot
        // on the decoded values, bit for bit (exact decodes).
        let scalar = super::super::scalar_kernels();
        for n in [0usize, 1, 7, 15, 16, 17, 33, 100, 257] {
            let codes: Vec<u16> =
                (0..n).map(|i| f16_from_f32((i as f32 * 0.37).sin())).collect();
            let q: Vec<f32> = (0..n).map(|i| (i as f32 * 0.73).cos()).collect();
            let decoded: Vec<f32> = codes.iter().map(|&h| f16_to_f32(h)).collect();
            assert_eq!(
                (F16_SCALAR.dot)(&codes, &q).to_bits(),
                (scalar.dot)(&decoded, &q).to_bits(),
                "f16 n={n}"
            );
            let bcodes: Vec<u16> =
                (0..n).map(|i| bf16_from_f32((i as f32 * 0.41).sin())).collect();
            let bdecoded: Vec<f32> = bcodes.iter().map(|&h| bf16_to_f32(h)).collect();
            assert_eq!(
                (BF16_SCALAR.dot)(&bcodes, &q).to_bits(),
                (scalar.dot)(&bdecoded, &q).to_bits(),
                "bf16 n={n}"
            );
            let icodes: Vec<i8> = (0..n).map(|i| (i as i32 % 255 - 127) as i8).collect();
            let idecoded: Vec<f32> = icodes.iter().map(|&c| c as f32).collect();
            assert_eq!(
                (INT8_SCALAR.dot)(&icodes, &q).to_bits(),
                (scalar.dot)(&idecoded, &q).to_bits(),
                "int8 n={n}"
            );
        }
    }

    #[test]
    fn format_isas_lists_all_four_formats() {
        let isas = format_isas();
        let names: Vec<&str> = isas.iter().map(|&(f, _)| f).collect();
        assert_eq!(names, vec!["f32", "f16", "bf16", "int8"]);
        for (_, isa) in isas {
            assert!(!isa.is_empty());
        }
    }
}
