//! NEON backend (aarch64, 128-bit lanes).
//!
//! NEON is architecturally mandatory on aarch64, so the dispatcher
//! selects this table unconditionally there; the `#[target_feature]`
//! annotations keep the kernels honest anyway. Accumulation order (the
//! per-row contract shared with the blocked kernels): four 4-lane FMA
//! accumulators over 16-float chunks, a 4-float cleanup loop into the
//! first accumulator, a fixed pairwise reduction, then a scalar tail.

use super::KernelTable;
use core::arch::aarch64::*;

pub(super) static TABLE: KernelTable = KernelTable {
    isa: "neon",
    dot,
    axpy,
    dist_sq,
    norm_sq,
    dot_rows,
    partial_dot_rows,
    // NEON has no arbitrary-index gather instruction; the scalar loop
    // is already optimal (and exact by construction).
    gather: super::scalar::gather,
};

fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // min() mirrors the scalar backend's zip-truncation semantics on a
    // release-mode length mismatch.
    let n = a.len().min(b.len());
    // SAFETY: NEON is mandatory on aarch64 (the only arch this module
    // compiles for); n is within both slices.
    unsafe { dot_neon(a.as_ptr(), b.as_ptr(), n) }
}

fn norm_sq(a: &[f32]) -> f32 {
    // SAFETY: as above.
    unsafe { dot_neon(a.as_ptr(), a.as_ptr(), a.len()) }
}

fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { axpy_neon(alpha, x, y) }
}

fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: as above.
    unsafe { dist_sq_neon(a, b) }
}

fn dot_rows(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    // Real asserts, not debug: the unsafe kernel reads out.len()*dim
    // floats from `block`, so a release-mode length mismatch from safe
    // code must panic (like the scalar backend's slicing would), not
    // read out of bounds.
    assert_eq!(block.len(), out.len() * dim, "dot_rows: block/out shape mismatch");
    assert_eq!(q.len(), dim, "dot_rows: query dim mismatch");
    // SAFETY: as above; shapes verified.
    unsafe { dot_rows_neon(block, dim, q, out) }
}

fn partial_dot_rows(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    // Real asserts: the unsafe kernel reads q.len() floats from every
    // row pointer.
    assert_eq!(rows.len(), out.len(), "partial_dot_rows: rows/out mismatch");
    assert!(
        rows.iter().all(|r| r.len() == q.len()),
        "partial_dot_rows: row/query length mismatch"
    );
    // SAFETY: as above; shapes verified.
    unsafe { partial_dot_rows_neon(rows, q, out) }
}

/// Single-row dot over raw pointers; the canonical accumulation order
/// the blocked kernels replicate per row.
#[target_feature(enable = "neon")]
unsafe fn dot_neon(pa: *const f32, pb: *const f32, n: usize) -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
        i += 16;
    }
    while i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        i += 4;
    }
    let mut sum = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while i < n {
        sum += *pa.add(i) * *pb.add(i);
        i += 1;
    }
    sum
}

/// Two rows dotted against one query, sharing every query register
/// load. Per-row accumulation is exactly [`dot_neon`]'s order.
#[target_feature(enable = "neon")]
unsafe fn dot2_neon(p0: *const f32, p1: *const f32, pq: *const f32, n: usize) -> [f32; 2] {
    let mut a00 = vdupq_n_f32(0.0);
    let mut a01 = vdupq_n_f32(0.0);
    let mut a02 = vdupq_n_f32(0.0);
    let mut a03 = vdupq_n_f32(0.0);
    let mut a10 = vdupq_n_f32(0.0);
    let mut a11 = vdupq_n_f32(0.0);
    let mut a12 = vdupq_n_f32(0.0);
    let mut a13 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 16 <= n {
        let q0 = vld1q_f32(pq.add(i));
        let q1 = vld1q_f32(pq.add(i + 4));
        let q2 = vld1q_f32(pq.add(i + 8));
        let q3 = vld1q_f32(pq.add(i + 12));
        a00 = vfmaq_f32(a00, vld1q_f32(p0.add(i)), q0);
        a01 = vfmaq_f32(a01, vld1q_f32(p0.add(i + 4)), q1);
        a02 = vfmaq_f32(a02, vld1q_f32(p0.add(i + 8)), q2);
        a03 = vfmaq_f32(a03, vld1q_f32(p0.add(i + 12)), q3);
        a10 = vfmaq_f32(a10, vld1q_f32(p1.add(i)), q0);
        a11 = vfmaq_f32(a11, vld1q_f32(p1.add(i + 4)), q1);
        a12 = vfmaq_f32(a12, vld1q_f32(p1.add(i + 8)), q2);
        a13 = vfmaq_f32(a13, vld1q_f32(p1.add(i + 12)), q3);
        i += 16;
    }
    while i + 4 <= n {
        let q0 = vld1q_f32(pq.add(i));
        a00 = vfmaq_f32(a00, vld1q_f32(p0.add(i)), q0);
        a10 = vfmaq_f32(a10, vld1q_f32(p1.add(i)), q0);
        i += 4;
    }
    let mut s0 = vaddvq_f32(vaddq_f32(vaddq_f32(a00, a01), vaddq_f32(a02, a03)));
    let mut s1 = vaddvq_f32(vaddq_f32(vaddq_f32(a10, a11), vaddq_f32(a12, a13)));
    while i < n {
        let qv = *pq.add(i);
        s0 += *p0.add(i) * qv;
        s1 += *p1.add(i) * qv;
        i += 1;
    }
    [s0, s1]
}

#[target_feature(enable = "neon")]
unsafe fn dot_rows_neon(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    let rows = out.len();
    let pq = q.as_ptr();
    let base = block.as_ptr();
    let mut r = 0usize;
    while r + 2 <= rows {
        let p0 = base.add(r * dim);
        let s = dot2_neon(p0, p0.add(dim), pq, dim);
        out[r] = s[0];
        out[r + 1] = s[1];
        r += 2;
    }
    while r < rows {
        out[r] = dot_neon(base.add(r * dim), pq, dim);
        r += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn partial_dot_rows_neon(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    let n = q.len();
    let pq = q.as_ptr();
    let mut r = 0usize;
    while r + 2 <= rows.len() {
        debug_assert!(rows[r].len() == n && rows[r + 1].len() == n);
        let s = dot2_neon(rows[r].as_ptr(), rows[r + 1].as_ptr(), pq, n);
        out[r] = s[0];
        out[r + 1] = s[1];
        r += 2;
    }
    while r < rows.len() {
        debug_assert_eq!(rows[r].len(), n);
        out[r] = dot_neon(rows[r].as_ptr(), pq, n);
        r += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let va = vdupq_n_f32(alpha);
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let yv = vld1q_f32(py.add(i));
        let xv = vld1q_f32(px.add(i));
        vst1q_f32(py.add(i), vfmaq_f32(yv, va, xv));
        i += 4;
    }
    while i < n {
        *py.add(i) += alpha * *px.add(i);
        i += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn dist_sq_neon(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 8 <= n {
        let d0 = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
        acc0 = vfmaq_f32(acc0, d0, d0);
        acc1 = vfmaq_f32(acc1, d1, d1);
        i += 8;
    }
    while i + 4 <= n {
        let d0 = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
        acc0 = vfmaq_f32(acc0, d0, d0);
        i += 4;
    }
    let mut sum = vaddvq_f32(vaddq_f32(acc0, acc1));
    while i < n {
        let d = *pa.add(i) - *pb.add(i);
        sum += d * d;
        i += 1;
    }
    sum
}
