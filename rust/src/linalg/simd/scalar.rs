//! Portable scalar backend: the always-available reference table.
//!
//! `dot` is byte-for-byte the pre-SIMD implementation that every result
//! in the repo was validated against — `RUST_PALLAS_FORCE_SCALAR=1`
//! therefore reproduces pre-subsystem numerics exactly on the MIPS
//! scoring paths (`dot`, `partial_dot`, `norm_sq`, `axpy`, and
//! everything built on them). One deliberate exception even under
//! forced scalar: `dist_sq` gained the same lane-accumulator structure
//! as `dot` (the pre-subsystem version was a bare sequential loop),
//! shifting distance floats by normal reassociation noise — never the
//! exact-path argmax. The blocked kernels are plain per-row loops over
//! `dot` (register-blocking buys nothing without vector registers),
//! which trivially satisfies the module's blocked-≡-single-row
//! bit-identity invariant.

/// Accumulator width of the scalar kernels: the form LLVM reliably
/// turns into packed FMAs under `-C target-cpu=native`.
const LANES: usize = 16;

/// Dot product, unrolled over 16 independent lane accumulators with a
/// pairwise (balanced-tree) reduction.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..LANES {
            acc[i] += xa[i] * xb[i];
        }
    }
    let mut tail = 0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    // Pairwise reduction keeps the summation tree balanced.
    let mut width = LANES / 2;
    while width > 0 {
        for i in 0..width {
            acc[i] += acc[i + width];
        }
        width /= 2;
    }
    acc[0] + tail
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean distance with the same lane-accumulator structure
/// as [`dot`] (the pre-subsystem version was a bare sequential loop
/// LLVM could not reassociate).
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for i in 0..LANES {
            let d = xa[i] - xb[i];
            acc[i] += d * d;
        }
    }
    let mut tail = 0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        tail += d * d;
    }
    let mut width = LANES / 2;
    while width > 0 {
        for i in 0..width {
            acc[i] += acc[i + width];
        }
        width /= 2;
    }
    acc[0] + tail
}

/// Squared L2 norm: exactly `dot(a, a)`.
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Blocked row scoring (per-row [`dot`]). Hard asserts keep shape
/// violations a panic on every backend — the scalar CI leg must fail
/// exactly where the AVX2/NEON legs would.
pub fn dot_rows(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    assert_eq!(block.len(), out.len() * dim, "dot_rows: block/out shape mismatch");
    assert_eq!(q.len(), dim, "dot_rows: query dim mismatch");
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&block[i * dim..(i + 1) * dim], q);
    }
}

/// Index gather `out[t] = src[idx[t]]` (pure data movement — identical
/// on every backend). Hard asserts mirror the SIMD backends, whose
/// hardware gathers read `src` unchecked after validation.
pub fn gather(src: &[f32], idx: &[u32], out: &mut [f32]) {
    assert_eq!(idx.len(), out.len(), "gather: idx/out length mismatch");
    assert!(
        idx.iter().all(|&j| (j as usize) < src.len()),
        "gather: index out of bounds"
    );
    for (o, &j) in out.iter_mut().zip(idx) {
        *o = src[j as usize];
    }
}

/// Scattered blocked scoring (per-row [`dot`] over pre-sliced windows).
/// Hard asserts, for the same cross-backend consistency as [`dot_rows`].
pub fn partial_dot_rows(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    assert_eq!(rows.len(), out.len(), "partial_dot_rows: rows/out mismatch");
    assert!(
        rows.iter().all(|r| r.len() == q.len()),
        "partial_dot_rows: row/query length mismatch"
    );
    for (r, o) in rows.iter().zip(out.iter_mut()) {
        *o = dot(r, q);
    }
}
