//! Runtime-dispatched SIMD kernel subsystem: the hardware floor of
//! every scoring path.
//!
//! Every flop in the system — the exact Naive scan, BOUNDEDME's
//! coordinate pull batches, the sharded sample-then-confirm rescore —
//! funnels through [`crate::linalg::dot`] and its siblings, which in
//! turn dispatch through this module. One [`KernelTable`] of plain `fn`
//! pointers is selected **once per process** and cached in a
//! [`OnceLock`]; after that first call, dispatch is a single relaxed
//! atomic load plus an indirect call.
//!
//! # Dispatch strategy
//!
//! * **x86-64, AVX-512**: `is_x86_feature_detected!("avx512f")` *plus*
//!   the `avx2`/`fma` checks (the gather kernel runs the AVX2
//!   `vgatherdps`, and a hypervisor can mask AVX2 independently)
//!   selects the `avx512` module's table (512-bit FMA kernels, 8-row
//!   blocking).
//! * **x86-64, AVX2**: otherwise `is_x86_feature_detected!("avx2") &&
//!   ("fma")` selects the `avx2` module's table (256-bit FMA kernels).
//! * **aarch64**: NEON is architecturally mandatory, so the `neon`
//!   module's table is selected unconditionally (128-bit FMA kernels).
//! * **everything else / no features detected**: the portable
//!   `scalar` table — the pre-SIMD reference implementation, which
//!   LLVM still auto-vectorizes under `-C target-cpu=native`.
//! * **`RUST_PALLAS_FORCE_SCALAR`** (any value other than empty or
//!   `"0"`): escape hatch that pins the scalar table regardless of
//!   detection — for debugging miscompiles, bisecting numerical drift,
//!   and the CI matrix leg that keeps the scalar path green. The
//!   variable is read once, at table-selection time.
//!
//! # Kernel set
//!
//! Five scalar primitives — `dot`, `axpy`, `dist_sq`, `norm_sq` (and
//! `partial_dot`, which is `dot` over sub-slices) — plus two *blocked*
//! kernels the scalar layer never had, and one data-movement kernel:
//!
//! * [`KernelTable::dot_rows`] scores one query against `R` contiguous
//!   dataset rows at a time, sharing each query register load across
//!   all rows of the block (AVX-512: 8 rows/block, AVX2: 4, NEON: 2).
//!   This is the shape of the Naive fused scan, the sharded confirm
//!   rescore, and the compacted survivor-panel scan.
//! * [`KernelTable::partial_dot_rows`] takes *scattered* pre-sliced row
//!   windows (`&[&[f32]]`) — one pull batch across a surviving arm set,
//!   the shape of BOUNDEDME's inner loop, where survivors are
//!   non-contiguous rows pulled over one dense coordinate run.
//! * [`KernelTable::gather`] is the index gather `out[t] = src[idx[t]]`
//!   — the staging primitive behind the per-query coordinate gather
//!   ([`crate::bandit::PullScratch::gather`]) and BOUNDEDME's survivor
//!   panel compaction ([`crate::bandit::PullPanel`]). Pure data
//!   movement: results are identical across every ISA (x86 backends use
//!   the hardware `vgatherdps`).
//!
//! [`prefetch_read`] rounds the set out: a best-effort software
//! prefetch hint the panel scan issues one row ahead of the blocked
//! kernels (no-op off x86-64).
//!
//! # The Storage axis: widening kernels ([`wide`])
//!
//! The mixed-precision dataset tier (`f16` / `bf16` / `int8` storage,
//! see [`crate::data::quant`]) adds a second dispatch axis: per
//! compressed format, [`wide`] holds a [`wide::WideKernels`] table of
//! `dot` / `dot_rows` / `partial_dot_rows` / `gather` kernels that load
//! compressed elements and widen them to f32 *in registers* (F16C /
//! AVX-512 / NEON integer widening), so the bandit's sampling tier
//! streams 2 or 4 bytes per coordinate instead of 4. The wide tables
//! follow this module's contracts — per-process [`OnceLock`] dispatch,
//! the `RUST_PALLAS_FORCE_SCALAR` pin, blocked ≡ dot per-row
//! bit-identity, exact gathers — and [`wide::format_isas`] reports the
//! per-format capability (`"f16c"`, `"avx2-widen"`, …) alongside
//! [`active_isa`] so benches and batteries know which formats are
//! hardware-backed on the runner.
//!
//! # Float-reassociation tolerance contract
//!
//! Different ISAs accumulate in different orders (scalar: 16 f32 lanes,
//! AVX2: 2×8-lane FMA vectors, NEON: 4×4-lane), so **results differ
//! across tables** by normal float-reassociation noise — callers must
//! treat cross-ISA scores as equal within ~1e-4 relative tolerance (the
//! property tests in `tests/simd_kernels.rs` pin this). Two identities
//! ARE guaranteed bit-for-bit, and the exact-path equivalence tests
//! lean on them:
//!
//! 1. **Within one process, dispatch is stable**: the table is selected
//!    once, so any two computations of the same dot in one run agree
//!    bitwise.
//! 2. **Within one table, blocked ≡ single-row**: `dot_rows` and
//!    `partial_dot_rows` replicate their table's `dot` accumulation
//!    order per row exactly (same chunk widths, same reduction tree,
//!    same scalar tail), so a fused batch scan produces bit-identical
//!    scores to the per-query path. Every backend must preserve this
//!    invariant — `tests/simd_kernels.rs` asserts it per table.
//!
//! # Adding an ISA
//!
//! 1. Add a `cfg(target_arch = ...)`-gated module exporting a
//!    `static TABLE: KernelTable` whose entries are safe wrappers over
//!    `#[target_feature]` kernels (the wrappers are sound because the
//!    table is only selectable after runtime detection).
//! 2. Keep the per-row accumulation of the blocked kernels identical to
//!    the module's own `dot` (invariant 2 above).
//! 3. Register it in the private `detect()` selector behind its feature
//!    check, most-specific first.
//! 4. Run `tests/simd_kernels.rs` — the property suite cross-checks
//!    every available table against the scalar reference.

use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;
mod scalar;
pub mod wide;

/// Environment variable pinning the scalar table (debug/CI escape
/// hatch). Any value other than empty or `"0"` forces scalar.
pub const FORCE_SCALAR_ENV: &str = "RUST_PALLAS_FORCE_SCALAR";

/// Recommended row-tile for fused scans built on
/// [`KernelTable::dot_rows`]: small enough that a tile of
/// serving-dimension rows stays cache-resident across a whole query
/// batch, large enough to amortize dispatch. Shared by the Naive fused
/// scan and the native engine so the hot paths tune together.
pub const SCAN_TILE: usize = 16;

/// One ISA's kernel set: plain `fn` pointers so the dispatched call is
/// a single indirect jump (no trait-object fat pointer, no enum match
/// per call).
#[derive(Clone, Copy)]
pub struct KernelTable {
    /// ISA label (`"scalar"`, `"avx2"`, `"avx512"`, `"neon"`) for logs
    /// and benches.
    pub isa: &'static str,
    /// Dot product of two equal-length slices.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `y += alpha * x` over equal-length slices.
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// Squared Euclidean distance of two equal-length slices.
    pub dist_sq: fn(&[f32], &[f32]) -> f32,
    /// Squared L2 norm (≡ `dot(a, a)` in every backend).
    pub norm_sq: fn(&[f32]) -> f32,
    /// Blocked row scoring: `out[i] = dot(block[i*dim .. (i+1)*dim], q)`
    /// with query register loads shared across the rows of a block.
    /// `block.len() == out.len() * dim`, `q.len() == dim`.
    pub dot_rows: fn(&[f32], usize, &[f32], &mut [f32]),
    /// Scattered blocked scoring over pre-sliced row windows:
    /// `out[i] = dot(rows[i], q)` with `rows[i].len() == q.len()` for
    /// all `i`. One BOUNDEDME pull batch across a survivor set.
    pub partial_dot_rows: fn(&[&[f32]], &[f32], &mut [f32]),
    /// Index gather `out[t] = src[idx[t]]` with
    /// `idx.len() == out.len()` and every index within `src`. Pure data
    /// movement (query gathers, survivor panel compaction): identical
    /// results on every backend, so it carries no tolerance caveats.
    pub gather: fn(&[f32], &[u32], &mut [f32]),
}

static SCALAR: KernelTable = KernelTable {
    isa: "scalar",
    dot: scalar::dot,
    axpy: scalar::axpy,
    dist_sq: scalar::dist_sq,
    norm_sq: scalar::norm_sq,
    dot_rows: scalar::dot_rows,
    partial_dot_rows: scalar::partial_dot_rows,
    gather: scalar::gather,
};

static ACTIVE: OnceLock<&'static KernelTable> = OnceLock::new();

/// The process-wide dispatched kernel table. First call runs feature
/// detection (honoring [`FORCE_SCALAR_ENV`]); subsequent calls are one
/// atomic load.
#[inline]
pub fn kernels() -> &'static KernelTable {
    *ACTIVE.get_or_init(|| select(force_scalar_requested()))
}

/// The always-available portable reference table (what
/// [`FORCE_SCALAR_ENV`] pins). Exposed so property tests and benches
/// can compare any table against it without re-execing the process.
pub fn scalar_kernels() -> &'static KernelTable {
    &SCALAR
}

/// ISA label of the dispatched table (`"scalar"`, `"avx2"`, `"avx512"`,
/// `"neon"`).
pub fn active_isa() -> &'static str {
    kernels().isa
}

/// Best-effort software prefetch of the cache line holding `p` into L1
/// with read intent; a no-op off x86-64. The survivor-panel scan issues
/// this one row ahead of the blocked kernels so the next panel row is
/// in cache by the time its dots start.
#[inline(always)]
pub fn prefetch_read(p: *const f32) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: PREFETCHT0 is architecturally non-faulting for any
    // address, and SSE is part of the x86-64 baseline.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8)
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// True when [`FORCE_SCALAR_ENV`] requests the scalar table.
pub fn force_scalar_requested() -> bool {
    match std::env::var(FORCE_SCALAR_ENV) {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    }
}

/// Table-selection policy, exposed for tests: `force_scalar` bypasses
/// detection exactly like the env var does (the env var is consulted by
/// [`kernels`], not here, so tests can exercise both branches
/// in-process).
pub fn select(force_scalar: bool) -> &'static KernelTable {
    if force_scalar {
        return &SCALAR;
    }
    detect()
}

/// Every table that is *runnable* on this machine right now: scalar
/// always, plus **each** detected ISA table (an AVX-512 machine lists
/// scalar, avx2, and avx512). Property tests iterate this to
/// cross-check all compiled-in backends, independently of which table
/// the process-wide dispatch pinned.
pub fn available_tables() -> Vec<&'static KernelTable> {
    #[allow(unused_mut)]
    let mut tables: Vec<&'static KernelTable> = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            tables.push(&avx2::TABLE);
            // The avx512 table's gather kernel executes the AVX2
            // vgatherdps, so it is only runnable when AVX2 is detected
            // too (a hypervisor can mask AVX2 while exposing AVX512F).
            if std::arch::is_x86_feature_detected!("avx512f") {
                tables.push(&avx512::TABLE);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        tables.push(&neon::TABLE);
    }
    tables
}

/// Runtime feature detection, most-specific ISA first.
#[allow(unreachable_code)] // the aarch64 arm returns unconditionally
fn detect() -> &'static KernelTable {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            // avx512 requires the avx2+fma leg too: its gather kernel
            // runs the AVX2 vgatherdps, and a hypervisor can mask AVX2
            // while exposing AVX512F — never select on avx512f alone.
            if std::arch::is_x86_feature_detected!("avx512f") {
                return &avx512::TABLE;
            }
            return &avx2::TABLE;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is architecturally mandatory on aarch64.
        return &neon::TABLE;
    }
    &SCALAR
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn force_scalar_selects_scalar() {
        assert_eq!(select(true).isa, "scalar");
        assert!(std::ptr::eq(select(true), scalar_kernels()));
    }

    #[test]
    fn dispatch_is_stable_and_listed() {
        let k = kernels();
        assert!(std::ptr::eq(k, kernels()), "dispatch must be cached");
        // The active table is either scalar (forced or undetected) or
        // one of the available tables.
        assert!(available_tables().iter().any(|t| std::ptr::eq(*t, select(false)))
            || std::ptr::eq(k, scalar_kernels()));
    }

    #[test]
    fn env_escape_hatch_respected_when_set() {
        // Only assertable when the harness actually set the variable
        // (the CI scalar matrix leg does); otherwise this is vacuous.
        if force_scalar_requested() {
            assert_eq!(active_isa(), "scalar");
        }
    }

    #[test]
    fn every_available_table_matches_naive_reference() {
        for table in available_tables() {
            for n in [0usize, 1, 3, 7, 8, 15, 16, 17, 31, 64, 100, 1000] {
                let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
                let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.73).cos()).collect();
                let want = naive_dot(&a, &b);
                let got = (table.dot)(&a, &b) as f64;
                assert!(
                    (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "{} dot n={n}: {got} vs {want}",
                    table.isa
                );
            }
        }
    }

    #[test]
    fn blocked_kernels_are_bit_identical_to_dot_per_table() {
        // Invariant 2 of the module contract: within one table,
        // dot_rows/partial_dot_rows ≡ dot per row, bit for bit.
        for table in available_tables() {
            for (rows, dim) in [(1usize, 33usize), (4, 16), (5, 0), (7, 129), (8, 8)] {
                let block: Vec<f32> =
                    (0..rows * dim).map(|i| (i as f32 * 0.11).sin()).collect();
                let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.19).cos()).collect();
                let mut out = vec![0f32; rows];
                (table.dot_rows)(&block, dim, &q, &mut out);
                let refs: Vec<&[f32]> =
                    (0..rows).map(|r| &block[r * dim..(r + 1) * dim]).collect();
                let mut pout = vec![0f32; rows];
                (table.partial_dot_rows)(&refs, &q, &mut pout);
                for r in 0..rows {
                    let single = (table.dot)(&block[r * dim..(r + 1) * dim], &q);
                    assert_eq!(
                        out[r].to_bits(),
                        single.to_bits(),
                        "{} dot_rows row {r} ({rows}x{dim})",
                        table.isa
                    );
                    assert_eq!(
                        pout[r].to_bits(),
                        single.to_bits(),
                        "{} partial_dot_rows row {r} ({rows}x{dim})",
                        table.isa
                    );
                }
            }
        }
    }

    #[test]
    fn gather_is_exact_per_table() {
        // Pure data movement: every backend must reproduce the indexed
        // loads exactly, including duplicate and reversed indices.
        for table in available_tables() {
            for n in [0usize, 1, 5, 8, 9, 16, 31, 100] {
                let src: Vec<f32> = (0..64).map(|i| (i as f32 * 0.53).sin()).collect();
                let idx: Vec<u32> =
                    (0..n).map(|t| ((t * 37 + 11) % src.len()) as u32).collect();
                let mut out = vec![0f32; n];
                (table.gather)(&src, &idx, &mut out);
                for t in 0..n {
                    assert_eq!(
                        out[t].to_bits(),
                        src[idx[t] as usize].to_bits(),
                        "{} gather n={n} t={t}",
                        table.isa
                    );
                }
            }
        }
    }

    #[test]
    fn axpy_dist_norm_match_reference_per_table() {
        for table in available_tables() {
            for n in [0usize, 1, 7, 8, 9, 16, 33, 257] {
                let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.29).sin()).collect();
                let y0: Vec<f32> = (0..n).map(|i| (i as f32 * 0.41).cos()).collect();
                let mut y = y0.clone();
                (table.axpy)(0.75, &x, &mut y);
                for i in 0..n {
                    let want = y0[i] as f64 + 0.75 * x[i] as f64;
                    assert!(
                        (y[i] as f64 - want).abs() < 1e-5,
                        "{} axpy n={n} i={i}",
                        table.isa
                    );
                }
                let want_d: f64 = x
                    .iter()
                    .zip(&y0)
                    .map(|(&a, &b)| (a as f64 - b as f64).powi(2))
                    .sum();
                let got_d = (table.dist_sq)(&x, &y0) as f64;
                assert!(
                    (got_d - want_d).abs() < 1e-3 * (1.0 + want_d),
                    "{} dist_sq n={n}",
                    table.isa
                );
                let want_n: f64 = x.iter().map(|&a| (a as f64).powi(2)).sum();
                let got_n = (table.norm_sq)(&x) as f64;
                assert!(
                    (got_n - want_n).abs() < 1e-3 * (1.0 + want_n),
                    "{} norm_sq n={n}",
                    table.isa
                );
            }
        }
    }
}
