//! AVX2+FMA backend (x86-64, 256-bit lanes).
//!
//! Every public entry is a safe wrapper over a `#[target_feature]`
//! kernel. SAFETY: the wrappers are sound because [`TABLE`] is only
//! selectable by the dispatcher after `is_x86_feature_detected!`
//! confirms both `avx2` and `fma` on the running CPU.
//!
//! Accumulation order (the per-row contract shared by `dot`, `dot_rows`
//! and `partial_dot_rows`, which the exact-path bit-identity tests pin):
//! two 8-lane FMA accumulators over 16-float chunks, one optional
//! 8-float chunk into the first accumulator, a fixed horizontal
//! reduction of `acc0 + acc1`, then a sequential scalar tail.

use super::KernelTable;
use core::arch::x86_64::*;

pub(super) static TABLE: KernelTable = KernelTable {
    isa: "avx2",
    dot,
    axpy,
    dist_sq,
    norm_sq,
    dot_rows,
    partial_dot_rows,
    gather,
};

fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // min() mirrors the scalar backend's zip-truncation semantics, so a
    // release-mode length mismatch degrades identically instead of
    // reading out of bounds.
    let n = a.len().min(b.len());
    // SAFETY: table selected only after avx2+fma detection (module
    // docs); n is within both slices.
    unsafe { dot_fma(a.as_ptr(), b.as_ptr(), n) }
}

fn norm_sq(a: &[f32]) -> f32 {
    // SAFETY: as above.
    unsafe { dot_fma(a.as_ptr(), a.as_ptr(), a.len()) }
}

fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { axpy_fma(alpha, x, y) }
}

fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: as above.
    unsafe { dist_sq_fma(a, b) }
}

fn dot_rows(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    // Real asserts, not debug: the unsafe kernel reads out.len()*dim
    // floats from `block`, so a release-mode length mismatch from safe
    // code must panic (like the scalar backend's slicing would), not
    // read out of bounds.
    assert_eq!(block.len(), out.len() * dim, "dot_rows: block/out shape mismatch");
    assert_eq!(q.len(), dim, "dot_rows: query dim mismatch");
    // SAFETY: as above; shapes verified.
    unsafe { dot_rows_fma(block, dim, q, out) }
}

fn partial_dot_rows(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    // Real asserts: the unsafe kernel reads q.len() floats from every
    // row pointer.
    assert_eq!(rows.len(), out.len(), "partial_dot_rows: rows/out mismatch");
    assert!(
        rows.iter().all(|r| r.len() == q.len()),
        "partial_dot_rows: row/query length mismatch"
    );
    // SAFETY: as above; shapes verified.
    unsafe { partial_dot_rows_fma(rows, q, out) }
}

fn gather(src: &[f32], idx: &[u32], out: &mut [f32]) {
    // Real asserts: `vgatherdps` reads `src` unchecked once the indices
    // are validated, so a bad index from safe code must panic exactly
    // like the scalar backend's indexing would.
    assert_eq!(idx.len(), out.len(), "gather: idx/out length mismatch");
    assert!(
        idx.iter().all(|&j| (j as usize) < src.len()),
        "gather: index out of bounds"
    );
    // SAFETY: table selected only after avx2+fma detection; indices
    // verified in bounds above.
    unsafe { gather_i32(src, idx, out) }
}

/// Hardware index gather, 8 lanes per `vgatherdps`, scalar remainder.
#[target_feature(enable = "avx2")]
unsafe fn gather_i32(src: &[f32], idx: &[u32], out: &mut [f32]) {
    let n = idx.len();
    let base = src.as_ptr();
    let pi = idx.as_ptr();
    let po = out.as_mut_ptr();
    let mut t = 0usize;
    while t + 8 <= n {
        let vi = _mm256_loadu_si256(pi.add(t) as *const __m256i);
        _mm256_storeu_ps(po.add(t), _mm256_i32gather_ps::<4>(base, vi));
        t += 8;
    }
    while t < n {
        *po.add(t) = *base.add(*pi.add(t) as usize);
        t += 1;
    }
}

/// Horizontal sum of a 256-bit vector. Fixed reduction order: fold the
/// two 128-bit halves, then the classic movehdup/movehl ladder.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let shuf = _mm_movehdup_ps(s);
    let sums = _mm_add_ps(s, shuf);
    let shuf2 = _mm_movehl_ps(shuf, sums);
    _mm_cvtss_f32(_mm_add_ss(sums, shuf2))
}

/// Single-row dot over raw pointers; the canonical accumulation order
/// every blocked kernel replicates per row.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_fma(pa: *const f32, pb: *const f32, n: usize) -> f32 {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i)),
            _mm256_loadu_ps(pb.add(i)),
            acc0,
        );
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i)),
            _mm256_loadu_ps(pb.add(i)),
            acc0,
        );
        i += 8;
    }
    let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
    while i < n {
        sum += *pa.add(i) * *pb.add(i);
        i += 1;
    }
    sum
}

/// Four rows dotted against one query, sharing every query register
/// load. Per-row accumulation is exactly [`dot_fma`]'s order.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot4_fma(
    p0: *const f32,
    p1: *const f32,
    p2: *const f32,
    p3: *const f32,
    pq: *const f32,
    n: usize,
) -> [f32; 4] {
    let mut a00 = _mm256_setzero_ps();
    let mut a01 = _mm256_setzero_ps();
    let mut a10 = _mm256_setzero_ps();
    let mut a11 = _mm256_setzero_ps();
    let mut a20 = _mm256_setzero_ps();
    let mut a21 = _mm256_setzero_ps();
    let mut a30 = _mm256_setzero_ps();
    let mut a31 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let q0 = _mm256_loadu_ps(pq.add(i));
        let q1 = _mm256_loadu_ps(pq.add(i + 8));
        a00 = _mm256_fmadd_ps(_mm256_loadu_ps(p0.add(i)), q0, a00);
        a01 = _mm256_fmadd_ps(_mm256_loadu_ps(p0.add(i + 8)), q1, a01);
        a10 = _mm256_fmadd_ps(_mm256_loadu_ps(p1.add(i)), q0, a10);
        a11 = _mm256_fmadd_ps(_mm256_loadu_ps(p1.add(i + 8)), q1, a11);
        a20 = _mm256_fmadd_ps(_mm256_loadu_ps(p2.add(i)), q0, a20);
        a21 = _mm256_fmadd_ps(_mm256_loadu_ps(p2.add(i + 8)), q1, a21);
        a30 = _mm256_fmadd_ps(_mm256_loadu_ps(p3.add(i)), q0, a30);
        a31 = _mm256_fmadd_ps(_mm256_loadu_ps(p3.add(i + 8)), q1, a31);
        i += 16;
    }
    if i + 8 <= n {
        let q0 = _mm256_loadu_ps(pq.add(i));
        a00 = _mm256_fmadd_ps(_mm256_loadu_ps(p0.add(i)), q0, a00);
        a10 = _mm256_fmadd_ps(_mm256_loadu_ps(p1.add(i)), q0, a10);
        a20 = _mm256_fmadd_ps(_mm256_loadu_ps(p2.add(i)), q0, a20);
        a30 = _mm256_fmadd_ps(_mm256_loadu_ps(p3.add(i)), q0, a30);
        i += 8;
    }
    let mut s0 = hsum256(_mm256_add_ps(a00, a01));
    let mut s1 = hsum256(_mm256_add_ps(a10, a11));
    let mut s2 = hsum256(_mm256_add_ps(a20, a21));
    let mut s3 = hsum256(_mm256_add_ps(a30, a31));
    while i < n {
        let qv = *pq.add(i);
        s0 += *p0.add(i) * qv;
        s1 += *p1.add(i) * qv;
        s2 += *p2.add(i) * qv;
        s3 += *p3.add(i) * qv;
        i += 1;
    }
    [s0, s1, s2, s3]
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_rows_fma(block: &[f32], dim: usize, q: &[f32], out: &mut [f32]) {
    let rows = out.len();
    let pq = q.as_ptr();
    let base = block.as_ptr();
    let mut r = 0usize;
    while r + 4 <= rows {
        let p0 = base.add(r * dim);
        let s = dot4_fma(p0, p0.add(dim), p0.add(2 * dim), p0.add(3 * dim), pq, dim);
        out[r] = s[0];
        out[r + 1] = s[1];
        out[r + 2] = s[2];
        out[r + 3] = s[3];
        r += 4;
    }
    while r < rows {
        out[r] = dot_fma(base.add(r * dim), pq, dim);
        r += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn partial_dot_rows_fma(rows: &[&[f32]], q: &[f32], out: &mut [f32]) {
    let n = q.len();
    let pq = q.as_ptr();
    let mut r = 0usize;
    while r + 4 <= rows.len() {
        debug_assert!(
            rows[r].len() == n
                && rows[r + 1].len() == n
                && rows[r + 2].len() == n
                && rows[r + 3].len() == n
        );
        let s = dot4_fma(
            rows[r].as_ptr(),
            rows[r + 1].as_ptr(),
            rows[r + 2].as_ptr(),
            rows[r + 3].as_ptr(),
            pq,
            n,
        );
        out[r] = s[0];
        out[r + 1] = s[1];
        out[r + 2] = s[2];
        out[r + 3] = s[3];
        r += 4;
    }
    while r < rows.len() {
        debug_assert_eq!(rows[r].len(), n);
        out[r] = dot_fma(rows[r].as_ptr(), pq, n);
        r += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_fma(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let va = _mm256_set1_ps(alpha);
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let yv = _mm256_loadu_ps(py.add(i));
        let xv = _mm256_loadu_ps(px.add(i));
        _mm256_storeu_ps(py.add(i), _mm256_fmadd_ps(va, xv, yv));
        i += 8;
    }
    while i < n {
        *py.add(i) += alpha * *px.add(i);
        i += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dist_sq_fma(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
        let d1 = _mm256_sub_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
        );
        acc0 = _mm256_fmadd_ps(d0, d0, acc0);
        acc1 = _mm256_fmadd_ps(d1, d1, acc1);
        i += 16;
    }
    if i + 8 <= n {
        let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
        acc0 = _mm256_fmadd_ps(d0, d0, acc0);
        i += 8;
    }
    let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
    while i < n {
        let d = *pa.add(i) - *pb.add(i);
        sum += d * d;
        i += 1;
    }
    sum
}
