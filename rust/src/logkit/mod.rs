//! Minimal leveled logging to stderr (the `log` crate is unavailable
//! offline).
//!
//! Provides the [`error!`]/[`warn!`]/[`info!`]/[`debug!`]/[`trace!`]
//! macros the serving layer uses. The level comes from `RUST_LOG`
//! (`off|error|warn|info|debug|trace`, default `info`; an unrecognized
//! value warns once to stderr and falls back to `info`) on first use,
//! or explicitly via [`set_level`] / [`set_off`]. Filtering is one
//! relaxed atomic load, so disabled call sites cost nothing
//! measurable.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or dropped-work conditions.
    Error = 1,
    /// Degraded but continuing (fallbacks, sheds).
    Warn = 2,
    /// Lifecycle events.
    Info = 3,
    /// Per-batch diagnostics.
    Debug = 4,
    /// Per-query firehose.
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Stored filter, shifted by one so 0 can stay "uninitialized":
/// 0 = read `RUST_LOG` lazily, [`FILTER_OFF`] = emit nothing,
/// otherwise `Level as u8 + 1`.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

/// The `RUST_LOG=off` filter value (below even `error`).
const FILTER_OFF: u8 = 1;

fn filter_from_env() -> u8 {
    match std::env::var("RUST_LOG").as_deref() {
        Ok("off") => FILTER_OFF,
        Ok("error") => Level::Error as u8 + 1,
        Ok("warn") => Level::Warn as u8 + 1,
        Ok("info") => Level::Info as u8 + 1,
        Ok("debug") => Level::Debug as u8 + 1,
        Ok("trace") => Level::Trace as u8 + 1,
        Ok("") | Err(_) => Level::Info as u8 + 1,
        Ok(other) => {
            warn_unrecognized(other);
            Level::Info as u8 + 1
        }
    }
}

/// One-time stderr warning for an unrecognized `RUST_LOG` value — the
/// old behavior silently defaulted to `info`, which made typos
/// (`RUST_LOG=verbose`) indistinguishable from intent.
fn warn_unrecognized(value: &str) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        eprintln!(
            "[{:<5}] RUST_LOG={value:?} is not recognized \
             (expected off|error|warn|info|debug|trace); defaulting to info",
            "WARN"
        );
    });
}

/// Set the maximum emitted level explicitly.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8 + 1, Ordering::Relaxed);
}

/// Disable all logging (the explicit form of `RUST_LOG=off`).
pub fn set_off() {
    MAX_LEVEL.store(FILTER_OFF, Ordering::Relaxed);
}

/// Initialize from `RUST_LOG` (also happens lazily on first log call).
pub fn init_from_env() {
    MAX_LEVEL.store(filter_from_env(), Ordering::Relaxed);
}

/// True when messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == 0 {
        init_from_env();
        max = MAX_LEVEL.load(Ordering::Relaxed);
    }
    (level as u8) < max
}

/// Emit one record (used by the macros; call those instead).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:<5}] {args}", level.label());
    }
}

/// Log at [`Level::Error`].
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Error, format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`].
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at [`Level::Info`].
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Info, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`].
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Debug, format_args!($($arg)*))
    };
}

/// Log at [`Level::Trace`].
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Trace, format_args!($($arg)*))
    };
}

pub use {debug, error, info, trace, warn};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Trace);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_off();
        assert!(!enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn macros_compile_and_emit() {
        set_level(Level::Trace);
        error!("e {}", 1);
        warn!("w");
        info!("i");
        debug!("d");
        trace!("t");
    }
}
