//! Minimal leveled logging to stderr (the `log` crate is unavailable
//! offline).
//!
//! Provides the [`error!`]/[`warn!`]/[`info!`]/[`debug!`]/[`trace!`]
//! macros the serving layer uses. The level comes from `RUST_LOG`
//! (`error|warn|info|debug|trace`, default `info`) on first use, or
//! explicitly via [`set_level`]. Filtering is one relaxed atomic load,
//! so disabled call sites cost nothing measurable.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or dropped-work conditions.
    Error = 1,
    /// Degraded but continuing (fallbacks, sheds).
    Warn = 2,
    /// Lifecycle events.
    Info = 3,
    /// Per-batch diagnostics.
    Debug = 4,
    /// Per-query firehose.
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// 0 = uninitialized (read RUST_LOG lazily).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

fn level_from_env() -> Level {
    match std::env::var("RUST_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    }
}

/// Set the maximum emitted level explicitly.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from `RUST_LOG` (also happens lazily on first log call).
pub fn init_from_env() {
    set_level(level_from_env());
}

/// True when messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == 0 {
        init_from_env();
        max = MAX_LEVEL.load(Ordering::Relaxed);
    }
    (level as u8) <= max
}

/// Emit one record (used by the macros; call those instead).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:<5}] {args}", level.label());
    }
}

/// Log at [`Level::Error`].
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Error, format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`].
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at [`Level::Info`].
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Info, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`].
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Debug, format_args!($($arg)*))
    };
}

/// Log at [`Level::Trace`].
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::logkit::emit($crate::logkit::Level::Trace, format_args!($($arg)*))
    };
}

pub use {debug, error, info, trace, warn};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Trace);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn macros_compile_and_emit() {
        set_level(Level::Trace);
        error!("e {}", 1);
        warn!("w");
        info!("i");
        debug!("d");
        trace!("t");
    }
}
