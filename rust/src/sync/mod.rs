//! Minimal concurrency substrate: a bounded **MPMC channel** built on
//! `Mutex` + `Condvar`, plus the non-blocking primitives the
//! event-driven coordinator reactor runs on.
//!
//! The image's offline crate set has no `crossbeam-channel`/`tokio`, so
//! the coordinator's router queue and batch distribution run on this
//! from-scratch channel. Semantics match what the coordinator needs:
//!
//! * bounded capacity with non-blocking [`Sender::try_send`]
//!   (backpressure) and blocking [`Sender::send`];
//! * multiple consumers ([`Receiver`] is `Clone`) with blocking
//!   [`Receiver::recv`], [`Receiver::recv_timeout`], and non-blocking
//!   [`Receiver::try_recv`];
//! * disconnect detection: `recv` on a channel whose senders are all
//!   dropped drains the buffer then errors; sends after all receivers
//!   drop error;
//! * **readiness notification** for event loops: a [`Waker`] is a
//!   latched wakeup handle, and a [`Selector`] watches any number of
//!   channels (of any element types) at once. A watched channel fires
//!   the waker on every state transition an event loop can care about —
//!   item pushed (readable), item popped (writable again after
//!   backpressure), last sender dropped, last receiver dropped — so the
//!   disconnect and backpressure semantics of the blocking paths carry
//!   over to the polling paths exactly.
//!
//! # The poll discipline (no lost wakeups)
//!
//! [`Waker::wake`] *latches*: it sets a pending flag that the next
//! [`Waker::wait`] consumes, even if the waiter was not yet parked. An
//! event loop is therefore race-free as long as it polls **before**
//! waiting:
//!
//! ```text
//! loop {
//!     while let Ok(x) = rx.try_recv() { … }   // poll: drain readiness
//!     …                                       // (a push here sets the latch)
//!     selector.wait();                        // parks only if no wake since last wait
//! }
//! ```
//!
//! Any push that lands between the final `try_recv` and the `wait`
//! leaves the latch set, so `wait` returns immediately and the loop
//! re-polls. Spurious wakeups only cost one extra poll pass.
//!
//! The [`epoch`] submodule adds the reclamation observer for
//! generation-swapped state ([`EpochGauge`]/[`EpochGuard`]): pinning is
//! `Arc` cloning, reclamation is the last clone dropping, and the gauge
//! makes "how many generations are still alive" observable with relaxed
//! atomics only.
//!
//! The [`ring`] submodule adds the flight recorder's lossy lock-free
//! slot ring ([`SlotRing`]): writers overwrite in submission order and
//! never block, readers snapshot without consuming.

pub mod epoch;
pub mod ring;

pub use epoch::{EpochGauge, EpochGuard};
pub use ring::SlotRing;

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Error returned by `send`/`try_send`. The rejected value is handed
/// back to the caller.
#[derive(PartialEq, Eq)]
pub enum SendError<T> {
    /// The channel is full (try_send only).
    Full(T),
    /// All receivers are gone.
    Disconnected(T),
}

impl<T> std::fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Full(_) => write!(f, "SendError::Full(..)"),
            Self::Disconnected(_) => write!(f, "SendError::Disconnected(..)"),
        }
    }
}

impl<T> std::fmt::Display for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Full(_) => write!(f, "channel full"),
            Self::Disconnected(_) => write!(f, "channel disconnected"),
        }
    }
}

impl<T> std::error::Error for SendError<T> {}

/// Error returned by `recv`.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Timed out waiting (recv_timeout only).
    Timeout,
    /// Buffer empty and all senders gone.
    Disconnected,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Timeout => write!(f, "recv timeout"),
            Self::Disconnected => write!(f, "channel disconnected"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Error returned by [`Receiver::try_recv`].
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    /// No item queued right now (senders still connected).
    Empty,
    /// Buffer empty and all senders gone.
    Disconnected,
}

impl std::fmt::Display for TryRecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Empty => write!(f, "channel empty"),
            Self::Disconnected => write!(f, "channel disconnected"),
        }
    }
}

impl std::error::Error for TryRecvError {}

struct WakerInner {
    pending: Mutex<bool>,
    cv: Condvar,
}

/// A latched wakeup handle: [`Waker::wake`] sets a pending flag and
/// wakes any parked waiter; [`Waker::wait`] parks until the flag is set
/// and consumes it. Because the flag latches, a wake delivered while
/// the consumer is *between* polls is not lost — the next `wait`
/// returns immediately (see the module docs for the poll discipline).
///
/// Cloning shares the handle: all clones observe the same latch.
#[derive(Clone)]
pub struct Waker(Arc<WakerInner>);

impl Default for Waker {
    fn default() -> Self {
        Self::new()
    }
}

impl Waker {
    /// Fresh handle with the latch clear.
    pub fn new() -> Self {
        Waker(Arc::new(WakerInner { pending: Mutex::new(false), cv: Condvar::new() }))
    }

    /// Latch a wakeup and notify parked waiters.
    pub fn wake(&self) {
        let mut p = self.0.pending.lock().unwrap();
        *p = true;
        drop(p);
        self.0.cv.notify_all();
    }

    /// Park until woken; consumes the latch.
    pub fn wait(&self) {
        let mut p = self.0.pending.lock().unwrap();
        while !*p {
            p = self.0.cv.wait(p).unwrap();
        }
        *p = false;
    }

    /// Park until woken or `deadline` passes. Returns `true` when woken
    /// (latch consumed), `false` on timeout (latch untouched).
    pub fn wait_deadline(&self, deadline: Instant) -> bool {
        let mut p = self.0.pending.lock().unwrap();
        while !*p {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self.0.cv.wait_timeout(p, deadline - now).unwrap();
            p = guard;
        }
        *p = false;
        true
    }

    /// [`Waker::wait_deadline`] with a relative timeout.
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        self.wait_deadline(Instant::now() + timeout)
    }
}

/// A multi-channel readiness notifier: the `select`/poll facility of
/// this substrate. Watch any number of channels — element types may
/// differ — then alternate *poll* ([`Receiver::try_recv`] /
/// [`Sender::try_send`] on each watched channel) with *wait*
/// ([`Selector::wait`] / [`Selector::wait_deadline`]).
///
/// Watching a [`Receiver`] (or a [`Sender`] — both halves share the
/// channel) arms the selector's [`Waker`] on every observable state
/// transition of that channel: push, pop, senders reaching zero,
/// receivers reaching zero. Readiness itself is *checked* by the
/// caller's non-blocking calls; the selector only says "something may
/// have changed" — classic level-check/edge-notify polling, with the
/// waker latch closing the check-then-park race.
#[derive(Clone, Default)]
pub struct Selector {
    waker: Waker,
}

impl Selector {
    /// Fresh selector with nothing watched.
    pub fn new() -> Self {
        Self { waker: Waker::new() }
    }

    /// The underlying wakeup handle (e.g. to fire it manually).
    pub fn waker(&self) -> &Waker {
        &self.waker
    }

    /// Watch a channel through its receiving half.
    pub fn watch<T>(&self, rx: &Receiver<T>) {
        rx.attach_waker(&self.waker);
    }

    /// Watch a channel through its sending half (useful when the event
    /// loop owns only senders and needs backpressure-relief wakeups).
    pub fn watch_sender<T>(&self, tx: &Sender<T>) {
        tx.attach_waker(&self.waker);
    }

    /// Park until any watched channel changes state (latched — see
    /// [`Waker::wait`]).
    pub fn wait(&self) {
        self.waker.wait();
    }

    /// Park until a state change or `deadline`; `true` when woken.
    pub fn wait_deadline(&self, deadline: Instant) -> bool {
        self.waker.wait_deadline(deadline)
    }
}

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receivers: usize,
    /// Wakers armed on every state transition (push / pop / either side
    /// disconnecting). Empty for channels nobody polls — the common
    /// case — so the notification cost is one `is_empty` check.
    wakers: Vec<Waker>,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cap: usize,
    /// Signaled when items are pushed or senders vanish.
    not_empty: Condvar,
    /// Signaled when items are popped or receivers vanish.
    not_full: Condvar,
}

/// Producer half (cloneable).
pub struct Sender<T>(Arc<Shared<T>>);

/// Consumer half (cloneable — MPMC).
pub struct Receiver<T>(Arc<Shared<T>>);

/// Fire every armed waker, called with the channel lock held. Safe and
/// allocation-free: [`Waker::wake`] takes only the waker's own (tiny)
/// mutex, and no code path acquires a channel lock while holding a
/// waker lock, so the ordering channel-lock → waker-lock cannot invert.
/// The common unwatched case is a single `is_empty` check.
fn fire<T>(st: &State<T>) {
    for w in &st.wakers {
        w.wake();
    }
}

/// Create a bounded channel with the given capacity (≥ 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let cap = cap.max(1);
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            queue: VecDeque::with_capacity(cap),
            senders: 1,
            receivers: 1,
            wakers: Vec::new(),
        }),
        cap,
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender(shared.clone()), Receiver(shared))
}

impl<T> Sender<T> {
    /// Non-blocking send; fails fast with `Full` under backpressure.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.state.lock().unwrap();
        if st.receivers == 0 {
            return Err(SendError::Disconnected(value));
        }
        if st.queue.len() >= self.0.cap {
            return Err(SendError::Full(value));
        }
        st.queue.push_back(value);
        fire(&st);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Blocking send; waits for space.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.state.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(SendError::Disconnected(value));
            }
            if st.queue.len() < self.0.cap {
                st.queue.push_back(value);
                fire(&st);
                drop(st);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Arm `waker` on every state transition of this channel (see
    /// [`Selector`]). Waker registrations live as long as the channel.
    pub fn attach_waker(&self, waker: &Waker) {
        self.0.state.lock().unwrap().wakers.push(waker.clone());
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; drains remaining items after senders disconnect,
    /// then errors.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.0.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                fire(&st);
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive: pops an item if one is queued, otherwise
    /// reports [`TryRecvError::Empty`] (senders alive) or
    /// [`TryRecvError::Disconnected`] (buffer drained and all senders
    /// gone — same drain-then-error contract as [`Receiver::recv`]).
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut st = self.0.state.lock().unwrap();
        if let Some(v) = st.queue.pop_front() {
            fire(&st);
            drop(st);
            self.0.not_full.notify_one();
            return Ok(v);
        }
        if st.senders == 0 {
            return Err(TryRecvError::Disconnected);
        }
        Err(TryRecvError::Empty)
    }

    /// Receive with a deadline.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.0.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                fire(&st);
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, res) = self.0.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.queue.is_empty() {
                if st.senders == 0 {
                    return Err(RecvError::Disconnected);
                }
                return Err(RecvError::Timeout);
            }
        }
    }

    /// Arm `waker` on every state transition of this channel (see
    /// [`Selector`]). Waker registrations live as long as the channel.
    pub fn attach_waker(&self, waker: &Waker) {
        self.0.state.lock().unwrap().wakers.push(waker.clone());
    }

    /// Number of queued items right now (diagnostics only).
    pub fn len(&self) -> usize {
        self.0.state.lock().unwrap().queue.len()
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.state.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.state.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            fire(&st);
            drop(st);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            fire(&st);
            drop(st);
            self.0.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_roundtrip() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn try_send_backpressure() {
        let (tx, rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(SendError::Full(3)));
        rx.recv().unwrap();
        tx.try_send(3).unwrap();
    }

    #[test]
    fn disconnect_on_sender_drop_after_drain() {
        let (tx, rx) = bounded(4);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn disconnect_on_receiver_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError::Disconnected(1)));
        assert_eq!(tx.try_send(2), Err(SendError::Disconnected(2)));
    }

    #[test]
    fn recv_timeout_fires() {
        let (_tx, rx) = bounded::<i32>(1);
        let t0 = Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(20)), Err(RecvError::Timeout));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn blocking_send_unblocks_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let h = thread::spawn(move || tx.send(2));
        thread::sleep(Duration::from_millis(10));
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn mpmc_conservation() {
        // 4 producers × 250 items, 3 consumers: every item delivered
        // exactly once.
        let (tx, rx) = bounded(16);
        let mut producers = Vec::new();
        for p in 0..4 {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..250 {
                    tx.send(p * 1000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        let mut expect: Vec<i32> =
            (0..4).flat_map(|p| (0..250).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn len_reports_queue_depth() {
        let (tx, rx) = bounded(8);
        assert!(rx.is_empty());
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.len(), 2);
    }

    #[test]
    fn try_recv_empty_item_disconnected() {
        let (tx, rx) = bounded(4);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        tx.send(9).unwrap();
        assert_eq!(rx.try_recv(), Ok(9));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        tx.send(10).unwrap();
        drop(tx);
        // Drain-then-error, same as recv().
        assert_eq!(rx.try_recv(), Ok(10));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn waker_latches_wake_before_wait() {
        let w = Waker::new();
        w.wake();
        // Latched: a pre-armed wake satisfies the next wait instantly.
        let t0 = Instant::now();
        w.wait();
        assert!(t0.elapsed() < Duration::from_millis(50));
        // Consumed: the wait after that times out.
        assert!(!w.wait_timeout(Duration::from_millis(10)));
    }

    #[test]
    fn waker_crosses_threads() {
        let w = Waker::new();
        let w2 = w.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            w2.wake();
        });
        assert!(w.wait_timeout(Duration::from_secs(5)));
        h.join().unwrap();
    }

    #[test]
    fn selector_wakes_on_push_pop_and_disconnect() {
        let sel = Selector::new();
        let (tx, rx) = bounded::<i32>(1);
        sel.watch(&rx);
        sel.watch_sender(&tx);

        // Push readiness.
        tx.send(1).unwrap();
        assert!(sel.wait_deadline(Instant::now() + Duration::from_millis(200)));
        // Pop (backpressure relief) readiness: channel was full.
        assert_eq!(tx.try_send(2), Err(SendError::Full(2)));
        assert_eq!(rx.recv().unwrap(), 1);
        assert!(sel.wait_deadline(Instant::now() + Duration::from_millis(200)));
        // Disconnect readiness.
        drop(tx);
        assert!(sel.wait_deadline(Instant::now() + Duration::from_millis(200)));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn selector_poll_loop_sees_every_item_across_two_channels() {
        // The reactor pattern: one selector over two channels of
        // different types, poll-then-wait, producers on other threads.
        let sel = Selector::new();
        let (tx_a, rx_a) = bounded::<u32>(4);
        let (tx_b, rx_b) = bounded::<String>(4);
        sel.watch(&rx_a);
        sel.watch(&rx_b);
        let ha = thread::spawn(move || {
            for i in 0..100u32 {
                tx_a.send(i).unwrap();
            }
        });
        let hb = thread::spawn(move || {
            for i in 0..100 {
                tx_b.send(format!("s{i}")).unwrap();
            }
        });
        let (mut got_a, mut got_b) = (0u32, 0u32);
        let (mut a_open, mut b_open) = (true, true);
        while a_open || b_open {
            let mut progressed = false;
            loop {
                match rx_a.try_recv() {
                    Ok(_) => {
                        got_a += 1;
                        progressed = true;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        a_open = false;
                        break;
                    }
                }
            }
            loop {
                match rx_b.try_recv() {
                    Ok(_) => {
                        got_b += 1;
                        progressed = true;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        b_open = false;
                        break;
                    }
                }
            }
            if !progressed && (a_open || b_open) {
                sel.wait();
            }
        }
        assert_eq!((got_a, got_b), (100, 100));
        ha.join().unwrap();
        hb.join().unwrap();
    }
}
