//! Minimal concurrency substrate: a bounded **MPMC channel** built on
//! `Mutex` + `Condvar`.
//!
//! The image's offline crate set has no `crossbeam-channel`/`tokio`, so
//! the coordinator's router queue and batch distribution run on this
//! from-scratch channel. Semantics match what the coordinator needs:
//!
//! * bounded capacity with non-blocking [`Sender::try_send`]
//!   (backpressure) and blocking [`Sender::send`];
//! * multiple consumers ([`Receiver`] is `Clone`) with blocking
//!   [`Receiver::recv`] and [`Receiver::recv_timeout`];
//! * disconnect detection: `recv` on a channel whose senders are all
//!   dropped drains the buffer then errors; sends after all receivers
//!   drop error.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Error returned by `send`/`try_send`. The rejected value is handed
/// back to the caller.
#[derive(PartialEq, Eq)]
pub enum SendError<T> {
    /// The channel is full (try_send only).
    Full(T),
    /// All receivers are gone.
    Disconnected(T),
}

impl<T> std::fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Full(_) => write!(f, "SendError::Full(..)"),
            Self::Disconnected(_) => write!(f, "SendError::Disconnected(..)"),
        }
    }
}

impl<T> std::fmt::Display for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Full(_) => write!(f, "channel full"),
            Self::Disconnected(_) => write!(f, "channel disconnected"),
        }
    }
}

impl<T> std::error::Error for SendError<T> {}

/// Error returned by `recv`.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Timed out waiting (recv_timeout only).
    Timeout,
    /// Buffer empty and all senders gone.
    Disconnected,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Timeout => write!(f, "recv timeout"),
            Self::Disconnected => write!(f, "channel disconnected"),
        }
    }
}

impl std::error::Error for RecvError {}

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cap: usize,
    /// Signaled when items are pushed or senders vanish.
    not_empty: Condvar,
    /// Signaled when items are popped or receivers vanish.
    not_full: Condvar,
}

/// Producer half (cloneable).
pub struct Sender<T>(Arc<Shared<T>>);

/// Consumer half (cloneable — MPMC).
pub struct Receiver<T>(Arc<Shared<T>>);

/// Create a bounded channel with the given capacity (≥ 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let cap = cap.max(1);
    let shared = Arc::new(Shared {
        state: Mutex::new(State { queue: VecDeque::with_capacity(cap), senders: 1, receivers: 1 }),
        cap,
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender(shared.clone()), Receiver(shared))
}

impl<T> Sender<T> {
    /// Non-blocking send; fails fast with `Full` under backpressure.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.state.lock().unwrap();
        if st.receivers == 0 {
            return Err(SendError::Disconnected(value));
        }
        if st.queue.len() >= self.0.cap {
            return Err(SendError::Full(value));
        }
        st.queue.push_back(value);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Blocking send; waits for space.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.state.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(SendError::Disconnected(value));
            }
            if st.queue.len() < self.0.cap {
                st.queue.push_back(value);
                drop(st);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; drains remaining items after senders disconnect,
    /// then errors.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.0.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a deadline.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.0.state.lock().unwrap();
        loop {
            if let Some(v) = st.queue.pop_front() {
                drop(st);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, res) = self.0.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if res.timed_out() && st.queue.is_empty() {
                if st.senders == 0 {
                    return Err(RecvError::Disconnected);
                }
                return Err(RecvError::Timeout);
            }
        }
    }

    /// Number of queued items right now (diagnostics only).
    pub fn len(&self) -> usize {
        self.0.state.lock().unwrap().queue.len()
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.state.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.state.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            drop(st);
            self.0.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_roundtrip() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn try_send_backpressure() {
        let (tx, rx) = bounded(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(SendError::Full(3)));
        rx.recv().unwrap();
        tx.try_send(3).unwrap();
    }

    #[test]
    fn disconnect_on_sender_drop_after_drain() {
        let (tx, rx) = bounded(4);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn disconnect_on_receiver_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError::Disconnected(1)));
        assert_eq!(tx.try_send(2), Err(SendError::Disconnected(2)));
    }

    #[test]
    fn recv_timeout_fires() {
        let (_tx, rx) = bounded::<i32>(1);
        let t0 = Instant::now();
        assert_eq!(rx.recv_timeout(Duration::from_millis(20)), Err(RecvError::Timeout));
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn blocking_send_unblocks_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let h = thread::spawn(move || tx.send(2));
        thread::sleep(Duration::from_millis(10));
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn mpmc_conservation() {
        // 4 producers × 250 items, 3 consumers: every item delivered
        // exactly once.
        let (tx, rx) = bounded(16);
        let mut producers = Vec::new();
        for p in 0..4 {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..250 {
                    tx.send(p * 1000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        let mut expect: Vec<i32> =
            (0..4).flat_map(|p| (0..250).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn len_reports_queue_depth() {
        let (tx, rx) = bounded(8);
        assert!(rx.is_empty());
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.len(), 2);
    }
}
