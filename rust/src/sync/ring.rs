//! Lock-free flight-recorder ring: a fixed-capacity slot array that
//! producers overwrite in submission order and readers snapshot without
//! consuming.
//!
//! The discipline mirrors `coordinator/stats.rs`: every shared word is
//! an atomic, there are no locks, and contention degrades gracefully
//! instead of blocking. Each slot carries a tiny state machine
//! (`EMPTY → BUSY → FULL`); a writer claims the next slot by CAS,
//! moves the value in, and releases it `FULL`. If the claim fails —
//! a reader is mid-snapshot on exactly that slot — the write is
//! **dropped** (and counted) rather than waited on: a flight recorder
//! must never stall the serving path it observes.
//!
//! Readers ([`SlotRing::snapshot_into`]) clone each `FULL` slot and put
//! it back, so the recorder keeps its history across server `trace`
//! calls; entries are only ever displaced by newer traces lapping the
//! ring.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

const EMPTY: u8 = 0;
const BUSY: u8 = 1;
const FULL: u8 = 2;

struct Slot<T> {
    state: AtomicU8,
    value: UnsafeCell<Option<T>>,
}

/// Lossy lock-free ring of the most recent ~`capacity` published
/// values. Writers never block; readers never consume.
pub struct SlotRing<T> {
    slots: Box<[Slot<T>]>,
    head: AtomicU64,
    dropped: AtomicU64,
}

// The UnsafeCell is only dereferenced while its slot's state is BUSY,
// and BUSY is only entered through a successful CAS — exactly one
// thread holds a slot at a time, so sharing the ring is sound whenever
// the payload itself can move between threads.
unsafe impl<T: Send> Send for SlotRing<T> {}
unsafe impl<T: Send> Sync for SlotRing<T> {}

impl<T: Clone> SlotRing<T> {
    /// Ring with room for `capacity` entries (at least 1).
    pub fn new(capacity: usize) -> Self {
        let slots: Vec<Slot<T>> = (0..capacity.max(1))
            .map(|_| Slot { state: AtomicU8::new(EMPTY), value: UnsafeCell::new(None) })
            .collect();
        SlotRing {
            slots: slots.into_boxed_slice(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Writes abandoned because a reader held the target slot.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Publish `value` into the next slot, overwriting whatever the
    /// ring lapped. Obstruction-free: if the slot is held by a
    /// concurrent snapshot, the value is dropped and counted instead
    /// of waiting.
    pub fn push(&self, value: T) {
        let i = (self.head.fetch_add(1, Ordering::Relaxed) as usize) % self.slots.len();
        let slot = &self.slots[i];
        let seen = slot.state.load(Ordering::Relaxed);
        if seen == BUSY
            || slot
                .state
                .compare_exchange(seen, BUSY, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: the CAS above made this thread the unique holder of
        // the BUSY slot.
        unsafe { *slot.value.get() = Some(value) };
        slot.state.store(FULL, Ordering::Release);
    }

    /// Clone every published entry into `out` without consuming it.
    /// Slots a writer holds at this instant are skipped (their next
    /// value shows up on the following snapshot).
    pub fn snapshot_into(&self, out: &mut Vec<T>) {
        for slot in self.slots.iter() {
            if slot
                .state
                .compare_exchange(FULL, BUSY, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            // SAFETY: the CAS above made this thread the unique holder
            // of the BUSY slot.
            let v = unsafe { (*slot.value.get()).clone() };
            slot.state.store(FULL, Ordering::Release);
            if let Some(v) = v {
                out.push(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn keeps_latest_on_wraparound() {
        let ring = SlotRing::new(4);
        for i in 0..10u64 {
            ring.push(i);
        }
        let mut got = Vec::new();
        ring.snapshot_into(&mut got);
        got.sort_unstable();
        assert_eq!(got, vec![6, 7, 8, 9]);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn snapshot_does_not_consume() {
        let ring = SlotRing::new(8);
        ring.push(41u64);
        ring.push(42);
        for _ in 0..3 {
            let mut got = Vec::new();
            ring.snapshot_into(&mut got);
            got.sort_unstable();
            assert_eq!(got, vec![41, 42]);
        }
    }

    #[test]
    fn capacity_floor_is_one() {
        let ring = SlotRing::new(0);
        assert_eq!(ring.capacity(), 1);
        ring.push(7u32);
        let mut got = Vec::new();
        ring.snapshot_into(&mut got);
        assert_eq!(got, vec![7]);
    }

    #[test]
    fn concurrent_writers_and_reader_stay_sound() {
        let ring = Arc::new(SlotRing::new(16));
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let ring = Arc::clone(&ring);
            hs.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    ring.push(t * 1_000_000 + i);
                }
            }));
        }
        let reader = {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                let mut seen = 0usize;
                for _ in 0..200 {
                    let mut got = Vec::new();
                    ring.snapshot_into(&mut got);
                    assert!(got.len() <= ring.capacity());
                    seen += got.len();
                }
                seen
            })
        };
        for h in hs {
            h.join().unwrap();
        }
        reader.join().unwrap();
        let mut fin = Vec::new();
        ring.snapshot_into(&mut fin);
        assert!(!fin.is_empty() && fin.len() <= 16);
        // Everything surviving must be a value some writer actually
        // produced.
        for v in fin {
            assert!(v % 1_000_000 < 2000 && v / 1_000_000 < 4);
        }
    }
}
