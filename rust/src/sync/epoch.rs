//! Epoch-based reclamation observer for generation-swapped state.
//!
//! The mutation subsystem publishes immutable generations behind
//! `Arc`s: a query *pins* the generation it was admitted under by
//! cloning the `Arc`, and reclamation is the last clone dropping — no
//! deferred free lists, no hazard pointers, because the data is
//! reference-counted to begin with. What `Arc` alone cannot answer is
//! the operational question *"how many generations are still alive
//! right now?"* — the signal a leak check or a churn bench needs to
//! prove that superseded generations actually drain once their pinned
//! queries finish.
//!
//! [`EpochGauge`] answers it with two atomics and an RAII guard:
//! every generation registers an [`EpochGuard`] at construction and
//! the guard's `Drop` retires it. All operations are single relaxed
//! atomic RMWs — registering/retiring a generation never takes a lock,
//! and reading the gauge is a plain load, so the gauge can sit on the
//! mutation path and be sampled from the serving path for free.
//!
//! Counter semantics are *eventually consistent* in the usual relaxed
//! sense: `alive()` observed concurrently with registrations/retires
//! may be off by in-flight increments, but once the system quiesces
//! (no builds in progress, all pinned queries drained) it is exact —
//! which is precisely the moment the leak check reads it.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

#[derive(Default)]
struct GaugeInner {
    alive: AtomicUsize,
    created: AtomicU64,
    peak: AtomicUsize,
}

/// Shared gauge counting live epochs (generations). Cheap to clone —
/// clones observe the same counters.
#[derive(Clone, Default)]
pub struct EpochGauge {
    inner: Arc<GaugeInner>,
}

impl EpochGauge {
    /// Fresh gauge with zero live epochs.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new epoch; the returned guard retires it on drop.
    pub fn register(&self) -> EpochGuard {
        let alive = self.inner.alive.fetch_add(1, Ordering::Relaxed) + 1;
        self.inner.created.fetch_add(1, Ordering::Relaxed);
        self.inner.peak.fetch_max(alive, Ordering::Relaxed);
        EpochGuard { inner: Arc::clone(&self.inner) }
    }

    /// Epochs currently alive (registered, guard not yet dropped).
    pub fn alive(&self) -> usize {
        self.inner.alive.load(Ordering::Relaxed)
    }

    /// Total epochs ever registered.
    pub fn created(&self) -> u64 {
        self.inner.created.load(Ordering::Relaxed)
    }

    /// High-water mark of concurrently alive epochs.
    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }
}

/// RAII registration token: dropping it retires the epoch. Not `Clone`
/// — exactly one retire per register.
pub struct EpochGuard {
    inner: Arc<GaugeInner>,
}

impl Drop for EpochGuard {
    fn drop(&mut self) {
        self.inner.alive.fetch_sub(1, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for EpochGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EpochGuard")
            .field("alive", &self.inner.alive.load(Ordering::Relaxed))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_drop_balance() {
        let g = EpochGauge::new();
        assert_eq!(g.alive(), 0);
        let a = g.register();
        let b = g.register();
        assert_eq!(g.alive(), 2);
        assert_eq!(g.created(), 2);
        assert_eq!(g.peak(), 2);
        drop(a);
        assert_eq!(g.alive(), 1);
        drop(b);
        assert_eq!(g.alive(), 0);
        // Peak and created survive retirement.
        assert_eq!(g.peak(), 2);
        assert_eq!(g.created(), 2);
    }

    #[test]
    fn clones_share_counters() {
        let g = EpochGauge::new();
        let g2 = g.clone();
        let guard = g2.register();
        assert_eq!(g.alive(), 1);
        drop(guard);
        assert_eq!(g.alive(), 0);
    }

    #[test]
    fn concurrent_register_retire_is_exact_at_quiesce() {
        let g = EpochGauge::new();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    let guard = g.register();
                    std::hint::black_box(&guard);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.alive(), 0);
        assert_eq!(g.created(), 8000);
        assert!(g.peak() >= 1);
    }
}
