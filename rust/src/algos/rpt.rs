//! RPT-MIPS (Keivani, Sinha & Ram 2017): randomized partition trees over
//! the Euclidean-transformed space.
//!
//! `L` independent trees; each internal node splits its items at the
//! median projection onto a random Gaussian direction; leaves hold at
//! most `leaf_size` items. A query descends every tree and exactly ranks
//! the union of the visited leaves. The success probability depends on a
//! potential function of `(q, S, L)` — not user-controllable (Table 1).

use super::transform::EuclideanTransform;
use super::{exact_rank, MipsIndex, MipsParams, MipsResult};
use crate::linalg::{dot, Matrix, Rng};
use std::time::Instant;

enum Node {
    Internal { dir: Vec<f32>, median: f32, left: u32, right: u32 },
    Leaf { items: Vec<u32> },
}

struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn build(
        data: &Matrix,
        transform: &EuclideanTransform,
        items: Vec<u32>,
        leaf_size: usize,
        rng: &mut Rng,
    ) -> Self {
        let mut nodes = Vec::new();
        Self::build_rec(data, transform, items, leaf_size, rng, &mut nodes);
        Tree { nodes }
    }

    /// Returns the index of the subtree root in `nodes`.
    fn build_rec(
        data: &Matrix,
        transform: &EuclideanTransform,
        items: Vec<u32>,
        leaf_size: usize,
        rng: &mut Rng,
        nodes: &mut Vec<Node>,
    ) -> u32 {
        if items.len() <= leaf_size {
            nodes.push(Node::Leaf { items });
            return (nodes.len() - 1) as u32;
        }
        let dim = data.cols() + 1;
        let dir: Vec<f32> = rng.gaussian_vec(dim);
        let mut proj: Vec<(f32, u32)> = items
            .iter()
            .map(|&i| (transform.project_item(data, &dir, i as usize), i))
            .collect();
        let mid = proj.len() / 2;
        proj.select_nth_unstable_by(mid, |a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal)
        });
        let median = proj[mid].0;
        let left_items: Vec<u32> = proj[..mid].iter().map(|&(_, i)| i).collect();
        let right_items: Vec<u32> = proj[mid..].iter().map(|&(_, i)| i).collect();
        let left = Self::build_rec(data, transform, left_items, leaf_size, rng, nodes);
        let right = Self::build_rec(data, transform, right_items, leaf_size, rng, nodes);
        nodes.push(Node::Internal { dir, median, left, right });
        (nodes.len() - 1) as u32
    }

    /// Root is the last node pushed.
    fn root(&self) -> u32 {
        (self.nodes.len() - 1) as u32
    }

    /// Descend with the transformed query; returns (leaf items, flops).
    fn descend(&self, qs: &[f32]) -> (&[u32], u64) {
        let mut node = self.root();
        let mut flops = 0u64;
        loop {
            match &self.nodes[node as usize] {
                Node::Leaf { items } => return (items, flops),
                Node::Internal { dir, median, left, right } => {
                    let s = dot(dir, qs);
                    flops += dir.len() as u64;
                    node = if s < *median { *left } else { *right };
                }
            }
        }
    }
}

/// RPT-MIPS index: `L` randomized partition trees.
pub struct RptMipsIndex {
    data: Matrix,
    transform: EuclideanTransform,
    trees: Vec<Tree>,
    prep_seconds: f64,
}

impl RptMipsIndex {
    /// Build `l_trees` trees with the given leaf size.
    pub fn new(data: Matrix, l_trees: usize, leaf_size: usize, seed: u64) -> Self {
        assert!(l_trees >= 1 && leaf_size >= 1);
        let t0 = Instant::now();
        let transform = EuclideanTransform::new(&data);
        let mut rng = Rng::new(seed);
        let all: Vec<u32> = (0..data.rows() as u32).collect();
        let trees = (0..l_trees)
            .map(|_| Tree::build(&data, &transform, all.clone(), leaf_size, &mut rng))
            .collect();
        let prep_seconds = t0.elapsed().as_secs_f64();
        Self { data, transform, trees, prep_seconds }
    }

    /// Number of trees `L`.
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

impl MipsIndex for RptMipsIndex {
    fn name(&self) -> &str {
        "RPT"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        self.prep_seconds
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let qs = self.transform.transform_query(q);
        let mut flops = q.len() as u64;
        let mut visited = vec![false; self.data.rows()];
        let mut candidates = Vec::new();
        for tree in &self.trees {
            let (items, f) = tree.descend(&qs);
            flops += f;
            for &i in items {
                if !visited[i as usize] {
                    visited[i as usize] = true;
                    candidates.push(i as usize);
                }
            }
        }
        let (ranked, rank_flops, cand_count) =
            exact_rank(&self.data, q, candidates, params.k);
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops: flops + rank_flops,
            candidates: cand_count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ground_truth;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn leaves_bounded_and_cover() {
        let idx = RptMipsIndex::new(gaussian(100, 8, 1), 1, 10, 2);
        let tree = &idx.trees[0];
        let mut all = Vec::new();
        for node in &tree.nodes {
            if let Node::Leaf { items } = node {
                assert!(items.len() <= 10);
                all.extend_from_slice(items);
            }
        }
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn many_trees_high_recall() {
        let data = gaussian(200, 12, 3);
        let idx = RptMipsIndex::new(data.clone(), 12, 20, 4);
        let mut hits = 0;
        for s in 0..20u64 {
            let q: Vec<f32> = Rng::new(70 + s).gaussian_vec(12);
            let res = idx.query(&q, &MipsParams { k: 1, ..Default::default() });
            if res.indices.first() == ground_truth(&data, &q, 1).first() {
                hits += 1;
            }
        }
        assert!(hits >= 14, "hits={hits}");
    }

    #[test]
    fn more_trees_more_candidates() {
        let data = gaussian(300, 8, 5);
        let one = RptMipsIndex::new(data.clone(), 1, 15, 6);
        let many = RptMipsIndex::new(data, 8, 15, 6);
        let q: Vec<f32> = Rng::new(80).gaussian_vec(8);
        let p = MipsParams { k: 1, ..Default::default() };
        assert!(many.query(&q, &p).candidates > one.query(&q, &p).candidates);
        assert_eq!(many.n_trees(), 8);
    }
}
