//! GREEDY-MIPS (Yu et al., NIPS 2017).
//!
//! Preprocessing sorts the items along every coordinate
//! (`O(N·n·log n)`). At query time, the entries `z_{ij} = q^(j)·v_i^(j)`
//! form `N` implicitly-sorted lists (one per coordinate, direction given
//! by `sign(q^(j))`); a heap-based *candidate screening* pass greedily
//! pops the globally largest `z` entries until `B` distinct items are
//! collected, which are then ranked exactly. The budget `B` is the only
//! accuracy knob — there is no suboptimality guarantee (Motivation II of
//! the BOUNDEDME paper).

use super::{exact_rank, MipsIndex, MipsParams, MipsResult};
use crate::linalg::Matrix;
use std::collections::BinaryHeap;
use std::time::Instant;

/// GREEDY-MIPS index: per-coordinate sorted item lists + budgeted
/// screening.
pub struct GreedyMipsIndex {
    data: Matrix,
    /// `sorted[j]` = item ids sorted by ascending `v^(j)`; the screening
    /// walks it from either end depending on `sign(q_j)`.
    sorted: Vec<Vec<u32>>,
    /// Candidate budget `B`.
    budget: usize,
    prep_seconds: f64,
}

/// Heap entry for the screening phase.
#[derive(PartialEq)]
struct Entry {
    z: f32,
    dim: u32,
    /// Steps taken along `sorted[dim]` (0 = best item for this dim).
    rank: u32,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.z
            .partial_cmp(&other.z)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(other.dim.cmp(&self.dim))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl GreedyMipsIndex {
    /// Build the per-coordinate sorted index. `budget` is the number of
    /// distinct candidates screened per query (the paper sweeps it from
    /// a few items to `n`).
    pub fn new(data: Matrix, budget: usize) -> Self {
        let t0 = Instant::now();
        let n = data.rows();
        let mut sorted = Vec::with_capacity(data.cols());
        let mut ids: Vec<u32> = (0..n as u32).collect();
        for j in 0..data.cols() {
            ids.sort_by(|&a, &b| {
                data.get(a as usize, j)
                    .partial_cmp(&data.get(b as usize, j))
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            sorted.push(ids.clone());
        }
        let prep_seconds = t0.elapsed().as_secs_f64();
        Self { data, sorted, budget: budget.max(1), prep_seconds }
    }

    /// Item id at screening rank `r` for dimension `dim` under query sign.
    #[inline]
    fn item_at(&self, dim: usize, rank: usize, positive: bool) -> u32 {
        let list = &self.sorted[dim];
        if positive {
            list[list.len() - 1 - rank]
        } else {
            list[rank]
        }
    }
}

impl MipsIndex for GreedyMipsIndex {
    fn name(&self) -> &str {
        "Greedy"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        self.prep_seconds
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let n = self.data.rows();
        let budget = self.budget.min(n);
        let mut flops = 0u64;

        // Seed the heap with each dimension's best entry.
        let mut heap = BinaryHeap::with_capacity(q.len());
        for (j, &qj) in q.iter().enumerate() {
            if qj == 0.0 || n == 0 {
                continue;
            }
            let item = self.item_at(j, 0, qj > 0.0);
            let z = qj * self.data.get(item as usize, j);
            flops += 1;
            heap.push(Entry { z, dim: j as u32, rank: 0 });
        }

        // Screening: pop globally-largest z entries, collect distinct items.
        let mut visited = vec![false; n];
        let mut candidates = Vec::with_capacity(budget);
        while candidates.len() < budget {
            let Some(Entry { dim, rank, .. }) = heap.pop() else { break };
            let dim_us = dim as usize;
            let qj = q[dim_us];
            let item = self.item_at(dim_us, rank as usize, qj > 0.0);
            if !visited[item as usize] {
                visited[item as usize] = true;
                candidates.push(item as usize);
            }
            let next = rank as usize + 1;
            if next < n {
                let nitem = self.item_at(dim_us, next, qj > 0.0);
                let z = qj * self.data.get(nitem as usize, dim_us);
                flops += 1;
                heap.push(Entry { z, dim, rank: next as u32 });
            }
        }

        let (ranked, rank_flops, cand_count) =
            exact_rank(&self.data, q, candidates, params.k);
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops: flops + rank_flops,
            candidates: cand_count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ground_truth;
    use crate::linalg::Rng;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn full_budget_is_exact() {
        let data = gaussian(60, 24, 1);
        let idx = GreedyMipsIndex::new(data.clone(), 60);
        let q: Vec<f32> = Rng::new(2).gaussian_vec(24);
        let res = idx.query(&q, &MipsParams { k: 5, ..Default::default() });
        assert_eq!(res.indices, ground_truth(&data, &q, 5));
        assert_eq!(res.candidates, 60);
    }

    #[test]
    fn small_budget_costs_less() {
        let data = gaussian(200, 32, 3);
        let big = GreedyMipsIndex::new(data.clone(), 200);
        let small = GreedyMipsIndex::new(data, 10);
        let q: Vec<f32> = Rng::new(4).gaussian_vec(32);
        let p = MipsParams { k: 5, ..Default::default() };
        let rb = big.query(&q, &p);
        let rs = small.query(&q, &p);
        assert!(rs.flops < rb.flops);
        assert!(rs.candidates <= 10);
    }

    #[test]
    fn screening_finds_dominant_item() {
        // One item dominates a coordinate the query emphasizes: a tiny
        // budget must still find it.
        let mut rows = vec![vec![0.0f32; 8]; 50];
        rows[33][2] = 100.0;
        let data = Matrix::from_rows(&rows);
        let idx = GreedyMipsIndex::new(data, 3);
        let mut q = vec![0.01f32; 8];
        q[2] = 1.0;
        let res = idx.query(&q, &MipsParams { k: 1, ..Default::default() });
        assert_eq!(res.indices[0], 33);
    }

    #[test]
    fn negative_query_coordinates_walk_ascending() {
        // Most-negative coordinate value wins when q_j < 0.
        let data = Matrix::from_rows(&[
            vec![5.0, 0.0],
            vec![-7.0, 0.0],
            vec![1.0, 0.0],
        ]);
        let idx = GreedyMipsIndex::new(data, 1);
        let res = idx.query(&[-1.0, 0.0], &MipsParams { k: 1, ..Default::default() });
        assert_eq!(res.indices[0], 1);
    }

    #[test]
    fn preprocessing_time_recorded() {
        let idx = GreedyMipsIndex::new(gaussian(100, 16, 5), 10);
        assert!(idx.preprocessing_seconds() > 0.0);
    }
}
