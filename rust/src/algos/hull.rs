//! Remark-1 extension: extreme-point filtering.
//!
//! The paper's Remark 1: BOUNDEDME is linear in `n`, and one can trade
//! its zero-preprocessing property for sublinearity in `n` by first
//! restricting the search to the extreme points of `conv(S)` — the MIPS
//! optimum `argmax_v qᵀv` is always attained at an extreme point, for
//! every query.
//!
//! Exact convex hulls are hopeless in high dimension, so
//! [`ExtremePointFilter`] uses the standard sampling approximation:
//! draw `m` random unit directions, keep the `t` maximizers of each
//! (every kept point is a *true* extreme point; the approximation is
//! that some faces may be missed). Recall of the filter is measured by
//! the `ablation_hull` bench; BOUNDEDME then runs over the filtered set
//! via [`BoundedMeHullIndex`], making the per-query cost
//! `O(|E|·√N/ε)` with `|E| ≪ n` on low-rank-ish data.

use super::bounded_me_index::column_maxima;
use super::{MipsIndex, MipsParams, MipsResult};
use crate::bandit::{BoundedMe, BoundedMeConfig, MatrixArms, PullOrder, RewardSource};
use crate::linalg::{dot, Matrix, Rng, TopK};
use std::time::Instant;

/// Approximate extreme-point set of a vector collection.
#[derive(Clone, Debug)]
pub struct ExtremePointFilter {
    /// Ids of the kept (extreme) points, sorted ascending.
    pub extreme_ids: Vec<u32>,
    /// Directions sampled.
    pub n_directions: usize,
}

impl ExtremePointFilter {
    /// Build by sampling `m` Gaussian directions and keeping the top `t`
    /// points of each (`O(m·n·N)` preprocessing).
    pub fn build(data: &Matrix, m: usize, t: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut keep = vec![false; data.rows()];
        for _ in 0..m {
            let dir = rng.gaussian_vec(data.cols());
            let mut top = TopK::new(t.max(1));
            for (i, row) in data.iter_rows().enumerate() {
                top.push(dot(row, &dir), i);
            }
            for id in top.into_indices() {
                keep[id] = true;
            }
        }
        let extreme_ids: Vec<u32> =
            keep.iter().enumerate().filter(|(_, &k)| k).map(|(i, _)| i as u32).collect();
        Self { extreme_ids, n_directions: m }
    }

    /// Fraction of the dataset kept.
    pub fn fraction(&self, n: usize) -> f64 {
        self.extreme_ids.len() as f64 / n.max(1) as f64
    }
}

/// BOUNDEDME over the extreme-point subset: sublinear in `n` when the
/// hull is small, at the cost of `O(m·n·N)` preprocessing — the exact
/// trade-off Remark 1 describes.
pub struct BoundedMeHullIndex {
    /// Full dataset (kept for exactness checks / fallback).
    data: Matrix,
    /// Gathered extreme-point rows (the search set).
    subset: Matrix,
    /// Map subset row → original id.
    ids: Vec<u32>,
    colmax: Vec<f32>,
    order: PullOrder,
    prep_seconds: f64,
}

impl BoundedMeHullIndex {
    /// Build the filter (`m` directions × top-`t`) and gather the subset.
    pub fn new(data: Matrix, m: usize, t: usize, seed: u64) -> Self {
        let t0 = Instant::now();
        let filter = ExtremePointFilter::build(&data, m, t, seed);
        let ids = filter.extreme_ids.clone();
        let subset = data.gather_rows(&ids.iter().map(|&i| i as usize).collect::<Vec<_>>());
        let colmax = column_maxima(&subset);
        let prep_seconds = t0.elapsed().as_secs_f64();
        Self { data, subset, ids, colmax, order: PullOrder::Permuted, prep_seconds }
    }

    /// Number of extreme points retained.
    pub fn n_extreme(&self) -> usize {
        self.ids.len()
    }
}

impl MipsIndex for BoundedMeHullIndex {
    fn name(&self) -> &str {
        "BoundedME+hull"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        self.prep_seconds
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let bound = self
            .colmax
            .iter()
            .zip(q)
            .fold(f32::MIN_POSITIVE, |m, (&c, &qj)| m.max(c * qj.abs()));
        let arms = MatrixArms::new(&self.subset, q, bound, self.order, params.seed);
        let eff_epsilon = params.epsilon * arms.range_width();
        let k = params.k.max(1).min(self.subset.rows().max(1));
        let algo = BoundedMe::new(BoundedMeConfig {
            k,
            epsilon: eff_epsilon.max(f64::MIN_POSITIVE),
            delta: params.delta.clamp(f64::MIN_POSITIVE, 1.0 - 1e-12),
        });
        let n_list = arms.list_len() as f64;
        let out = algo.run(&arms);
        MipsResult {
            indices: out.result.arms.iter().map(|&i| self.ids[i] as usize).collect(),
            scores: out.result.means.iter().map(|&m| (m * n_list) as f32).collect(),
            flops: out.result.total_pulls,
            candidates: self.subset.rows(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ground_truth;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn filter_keeps_true_extremes_in_2d() {
        // Square corners + interior points: corners must be kept, the
        // center must be droppable.
        let mut rows = vec![
            vec![1.0f32, 1.0],
            vec![1.0, -1.0],
            vec![-1.0, 1.0],
            vec![-1.0, -1.0],
        ];
        for i in 0..40 {
            let a = i as f32 / 40.0 * 0.5;
            rows.push(vec![a * 0.3, -a * 0.2]); // interior
        }
        let data = Matrix::from_rows(&rows);
        let f = ExtremePointFilter::build(&data, 64, 1, 1);
        for corner in 0..4 {
            assert!(
                f.extreme_ids.contains(&(corner as u32)),
                "corner {corner} missing from {:?}",
                f.extreme_ids
            );
        }
        assert!(f.fraction(data.rows()) < 0.5, "filter kept too much");
    }

    #[test]
    fn hull_index_finds_optimum_on_low_rank_data() {
        // Low-rank data has few extreme points; the hull filter should
        // retain the MIPS winner for most queries.
        let ds = crate::data::synthetic::low_rank_dataset(300, 64, 3, 0.01, 2);
        let idx = BoundedMeHullIndex::new(ds.vectors.clone(), 128, 2, 3);
        assert!(idx.n_extreme() < 300);
        let mut hits = 0;
        for s in 0..10u64 {
            let q = ds.sample_query(s);
            let truth = ground_truth(&ds.vectors, &q, 1)[0];
            let res =
                idx.query(&q, &MipsParams { k: 1, epsilon: 1e-9, delta: 0.05, seed: s });
            if res.indices[0] == truth {
                hits += 1;
            }
        }
        assert!(hits >= 8, "hull recall {hits}/10");
    }

    #[test]
    fn hull_query_cheaper_than_full() {
        let data = gaussian(400, 128, 4);
        let full = crate::algos::BoundedMeIndex::new(data.clone());
        let hull = BoundedMeHullIndex::new(data, 32, 1, 5);
        let q: Vec<f32> = Rng::new(6).gaussian_vec(128);
        let p = MipsParams { k: 1, epsilon: 0.1, delta: 0.1, seed: 7 };
        let rf = full.query(&q, &p);
        let rh = hull.query(&q, &p);
        assert!(rh.flops < rf.flops, "{} !< {}", rh.flops, rf.flops);
        assert!(hull.preprocessing_seconds() > 0.0);
    }

    #[test]
    fn ids_map_back_to_original() {
        let data = gaussian(50, 16, 8);
        let idx = BoundedMeHullIndex::new(data.clone(), 16, 1, 9);
        let q: Vec<f32> = Rng::new(10).gaussian_vec(16);
        let res = idx.query(&q, &MipsParams { k: 3, epsilon: 1e-9, delta: 0.1, seed: 0 });
        for &id in &res.indices {
            assert!(id < 50);
        }
    }
}
