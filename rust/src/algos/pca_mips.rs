//! PCA-MIPS (Bachrach et al., RecSys 2014).
//!
//! After the Euclidean transform, a *PCA tree* of depth `d` is built:
//! level `ℓ` splits every node at the median projection onto the `ℓ`-th
//! principal component of the transformed data. A query descends to one
//! leaf (`d` projections) and exactly ranks the `≈ n/2^d` items there.
//! The depth `d` is the accuracy knob; there is no suboptimality
//! guarantee.

use super::transform::EuclideanTransform;
use super::{exact_rank, MipsIndex, MipsParams, MipsResult};
use crate::linalg::pca::{pca, Pca};
use crate::linalg::Matrix;
use std::time::Instant;

/// PCA-tree MIPS index.
pub struct PcaMipsIndex {
    data: Matrix,
    transform: EuclideanTransform,
    pca: Pca,
    depth: usize,
    /// Heap-layout medians for the complete binary tree:
    /// `medians[node]`, node ∈ [1, 2^d), children of `v` are `2v, 2v+1`.
    medians: Vec<f32>,
    /// Leaf buckets, indexed by `leaf = node − 2^d`.
    leaves: Vec<Vec<u32>>,
    prep_seconds: f64,
}

impl PcaMipsIndex {
    /// Build a PCA tree of the given depth (`2^depth` leaves).
    /// Preprocessing is `O(N²n)`-flavored in the paper's accounting
    /// (PCA); ours is `O(d·iters·n·N)` power iteration.
    pub fn new(data: Matrix, depth: usize, seed: u64) -> Self {
        assert!(depth >= 1 && depth <= 24, "depth out of range");
        let t0 = Instant::now();
        let transform = EuclideanTransform::new(&data);
        let n = data.rows();
        let dim = data.cols() + 1;

        // Materialize the augmented matrix once for PCA (dropped after).
        let mut aug_data = Vec::with_capacity(n * dim);
        for i in 0..n {
            for &x in data.row(i) {
                aug_data.push(x * transform.inv_scale);
            }
            aug_data.push(transform.aug[i]);
        }
        let aug = Matrix::from_vec(n, dim, aug_data);
        let p = pca(&aug, depth, 30, seed);

        // Per-item projections on each component (n × depth, transient).
        let k = p.components.rows(); // may be < depth on tiny data
        let depth = k;
        let proj: Vec<Vec<f32>> = (0..depth)
            .map(|c| (0..n).map(|i| p.project(aug.row(i), c)).collect())
            .collect();

        // Build the complete tree by recursive median partitioning.
        let n_internal = 1usize << depth;
        let mut medians = vec![0f32; n_internal]; // index 1..2^d-1 used
        let mut leaves: Vec<Vec<u32>> = vec![Vec::new(); 1 << depth];
        let mut stack: Vec<(usize, usize, Vec<u32>)> =
            vec![(1, 0, (0..n as u32).collect())];
        while let Some((node, level, mut items)) = stack.pop() {
            if level == depth {
                leaves[node - n_internal] = items;
                continue;
            }
            // Median of this node's items along component `level`.
            let m = median_of(&mut items, &proj[level]);
            medians[node] = m;
            let (mut left, mut right) = (Vec::new(), Vec::new());
            for &i in &items {
                if proj[level][i as usize] <= m {
                    left.push(i);
                } else {
                    right.push(i);
                }
            }
            // Degenerate split (all projections equal): force a balanced cut
            // so the tree keeps its depth.
            if left.is_empty() || right.is_empty() {
                let mid = items.len() / 2;
                left = items[..mid].to_vec();
                right = items[mid..].to_vec();
            }
            stack.push((2 * node, level + 1, left));
            stack.push((2 * node + 1, level + 1, right));
        }

        let prep_seconds = t0.elapsed().as_secs_f64();
        Self { data, transform, pca: p, depth, medians, leaves, prep_seconds }
    }

    /// Tree depth actually built.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Size of the leaf a query would visit, averaged.
    pub fn mean_leaf_size(&self) -> f64 {
        let total: usize = self.leaves.iter().map(|l| l.len()).sum();
        total as f64 / self.leaves.len() as f64
    }
}

/// Median of `proj[item]` over `items` (mutates order of `items`).
fn median_of(items: &mut [u32], proj: &[f32]) -> f32 {
    let mid = items.len() / 2;
    if items.is_empty() {
        return 0.0;
    }
    items.select_nth_unstable_by(mid.min(items.len() - 1), |&a, &b| {
        proj[a as usize]
            .partial_cmp(&proj[b as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    proj[items[mid.min(items.len() - 1)] as usize]
}

impl MipsIndex for PcaMipsIndex {
    fn name(&self) -> &str {
        "PCA"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        self.prep_seconds
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let qs = self.transform.transform_query(q);
        let mut flops = q.len() as u64; // normalization
        let mut node = 1usize;
        for level in 0..self.depth {
            let s = self.pca.project(&qs, level);
            flops += qs.len() as u64;
            node = if s <= self.medians[node] { 2 * node } else { 2 * node + 1 };
        }
        let leaf = &self.leaves[node - (1 << self.depth)];
        let (ranked, rank_flops, cand_count) =
            exact_rank(&self.data, q, leaf.iter().map(|&i| i as usize), params.k);
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops: flops + rank_flops,
            candidates: cand_count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ground_truth;
    use crate::linalg::Rng;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn leaves_partition_items() {
        let idx = PcaMipsIndex::new(gaussian(128, 16, 1), 3, 7);
        let mut all: Vec<u32> = idx.leaves.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..128).collect::<Vec<_>>());
        assert_eq!(idx.leaves.len(), 8);
    }

    #[test]
    fn balanced_leaves() {
        let idx = PcaMipsIndex::new(gaussian(256, 12, 2), 3, 3);
        for leaf in &idx.leaves {
            // Median splits: every leaf within 2x of n/2^d.
            assert!(leaf.len() >= 16 && leaf.len() <= 64, "leaf size {}", leaf.len());
        }
    }

    #[test]
    fn shallow_tree_high_recall() {
        let data = gaussian(200, 16, 3);
        let idx = PcaMipsIndex::new(data.clone(), 1, 5);
        let mut hits = 0;
        for s in 0..20u64 {
            let q: Vec<f32> = Rng::new(50 + s).gaussian_vec(16);
            let res = idx.query(&q, &MipsParams { k: 1, ..Default::default() });
            if res.indices.first() == ground_truth(&data, &q, 1).first() {
                hits += 1;
            }
        }
        // depth 1 scans half the data on average; recall should be decent.
        assert!(hits >= 12, "hits={hits}");
    }

    #[test]
    fn deeper_tree_fewer_flops() {
        let data = gaussian(512, 16, 4);
        let shallow = PcaMipsIndex::new(data.clone(), 1, 5);
        let deep = PcaMipsIndex::new(data, 5, 5);
        let q: Vec<f32> = Rng::new(60).gaussian_vec(16);
        let p = MipsParams { k: 1, ..Default::default() };
        assert!(deep.query(&q, &p).flops < shallow.query(&q, &p).flops);
    }

    #[test]
    fn constant_data_does_not_panic() {
        let data = Matrix::from_rows(&vec![vec![1.0f32; 8]; 32]);
        let idx = PcaMipsIndex::new(data, 3, 6);
        let res = idx.query(&[1.0; 8], &MipsParams { k: 2, ..Default::default() });
        assert_eq!(res.indices.len(), 2);
    }
}
