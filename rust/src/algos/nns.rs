//! Nearest Neighbor Search via MAB-BP — the paper's second instantiation.
//!
//! MAB-BP solves any `argmax_i Σ_j f(i, j)`; for NNS
//! `f(i, j) = −(q^(j) − v_i^(j))²`, so the best arm is the vector
//! minimizing squared Euclidean distance. [`NnsArms`] adapts
//! [`RewardSource`] to that reward, and [`BoundedMeNnsIndex`] wraps it
//! in the same preprocessing-free, (ε, δ)-controlled interface.

use super::MipsParams;
use crate::bandit::{BoundedMe, BoundedMeConfig, PullOrder, RewardSource};
use crate::linalg::{Matrix, Rng};

/// NNS as MAB-BP: reward `j` of arm `i` is `−(q^(j) − v_i^(j))²`.
pub struct NnsArms<'a> {
    data: &'a Matrix,
    /// Query gathered in pull order.
    qp: Vec<f32>,
    perm: Option<Vec<u32>>,
    /// Rewards lie in `[−range_sq, 0]`.
    range_sq: f64,
}

impl<'a> NnsArms<'a> {
    /// Build for one query. `coord_bound` must satisfy
    /// `|q^(j) − v_i^(j)| ≤ coord_bound` for all `i, j` (e.g.
    /// `max|q_j| + colmax_j`, maximized over `j`).
    pub fn new(
        data: &'a Matrix,
        query: &[f32],
        coord_bound: f32,
        order: PullOrder,
        seed: u64,
    ) -> Self {
        assert_eq!(query.len(), data.cols());
        let n = data.cols();
        let mut rng = Rng::new(seed);
        let perm: Option<Vec<u32>> = match order {
            PullOrder::Sequential => None,
            PullOrder::Permuted => {
                let mut p: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut p);
                Some(p)
            }
            PullOrder::BlockShuffled(w) => {
                let w = w.max(1).min(n.max(1));
                let nblocks = n.div_ceil(w);
                let mut blocks: Vec<usize> = (0..nblocks).collect();
                rng.shuffle(&mut blocks);
                let mut p = Vec::with_capacity(n);
                for &blk in &blocks {
                    let lo = blk * w;
                    let hi = (lo + w).min(n);
                    p.extend((lo as u32)..(hi as u32));
                }
                Some(p)
            }
        };
        let qp = match &perm {
            None => query.to_vec(),
            Some(p) => p.iter().map(|&j| query[j as usize]).collect(),
        };
        let b = coord_bound.max(f32::MIN_POSITIVE) as f64;
        Self { data, qp, perm, range_sq: b * b }
    }

    #[inline]
    fn reward_at(&self, arm: usize, pos: usize) -> f64 {
        let row = self.data.row(arm);
        let (v, q) = match &self.perm {
            None => (row[pos], self.qp[pos]),
            Some(p) => (row[p[pos] as usize], self.qp[pos]),
        };
        let d = (q - v) as f64;
        -d * d
    }
}

impl RewardSource for NnsArms<'_> {
    fn n_arms(&self) -> usize {
        self.data.rows()
    }

    fn list_len(&self) -> usize {
        self.data.cols()
    }

    fn reward_range(&self) -> (f64, f64) {
        (-self.range_sq, 0.0)
    }

    fn pull_range(&self, arm: usize, from: usize, to: usize) -> f64 {
        let mut s = 0f64;
        for pos in from..to {
            s += self.reward_at(arm, pos);
        }
        s
    }

    fn pull_iid(&self, arm: usize, rng: &mut Rng) -> f64 {
        self.reward_at(arm, rng.next_below(self.list_len()))
    }

    fn true_mean(&self, arm: usize) -> f64 {
        self.pull_range(arm, 0, self.list_len()) / self.list_len() as f64
    }
}

/// Result of an NNS query.
#[derive(Clone, Debug)]
pub struct NnsResult {
    /// Indices of the (approximate) nearest neighbors, nearest first.
    pub indices: Vec<usize>,
    /// Estimated squared distances (from empirical means × N).
    pub distances_sq: Vec<f32>,
    /// Coordinate squared-difference evaluations performed.
    pub flops: u64,
}

/// Preprocessing-free K-nearest-neighbor search with the BOUNDEDME
/// (ε, δ) guarantee: the returned set's K-th distance exceeds the true
/// K-th distance by at most `ε·range` (mean-reward units) with
/// probability ≥ 1 − δ.
pub struct BoundedMeNnsIndex {
    data: Matrix,
    colmax: Vec<f32>,
    order: PullOrder,
}

impl BoundedMeNnsIndex {
    /// Wrap a vector set (one colmax scan, no structure built).
    pub fn new(data: Matrix) -> Self {
        Self::with_order(data, PullOrder::Permuted)
    }

    /// Wrap with an explicit pull order.
    pub fn with_order(data: Matrix, order: PullOrder) -> Self {
        let colmax = super::bounded_me_index::column_maxima(&data);
        Self { data, colmax, order }
    }

    /// Per-query coordinate-difference bound
    /// `max_j (|q_j| + colmax_j)`.
    pub fn coord_bound(&self, q: &[f32]) -> f32 {
        self.colmax
            .iter()
            .zip(q)
            .fold(f32::MIN_POSITIVE, |m, (&c, &qj)| m.max(c + qj.abs()))
    }

    /// The indexed vectors.
    pub fn data(&self) -> &Matrix {
        &self.data
    }

    /// K nearest neighbors with the (ε, δ) knob (ε relative to the
    /// reward range, as in MIPS).
    pub fn query(&self, q: &[f32], params: &MipsParams) -> NnsResult {
        let bound = self.coord_bound(q);
        let arms = NnsArms::new(&self.data, q, bound, self.order, params.seed);
        let eff_epsilon = params.epsilon * arms.range_width();
        let algo = BoundedMe::new(BoundedMeConfig {
            k: params.k.max(1),
            epsilon: eff_epsilon.max(f64::MIN_POSITIVE),
            delta: params.delta.clamp(f64::MIN_POSITIVE, 1.0 - 1e-12),
        });
        let n_list = arms.list_len() as f64;
        let out = algo.run(&arms);
        NnsResult {
            indices: out.result.arms,
            distances_sq: out
                .result
                .means
                .iter()
                .map(|&m| (-m * n_list) as f32)
                .collect(),
            flops: out.result.total_pulls,
        }
    }
}

/// Exact K-nearest-neighbors by exhaustive scan (ground truth).
pub fn nns_ground_truth(data: &Matrix, q: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..data.rows()).collect();
    idx.sort_by(|&a, &b| {
        crate::linalg::dist_sq(data.row(a), q)
            .partial_cmp(&crate::linalg::dist_sq(data.row(b), q))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn nns_arms_true_mean_is_neg_dist() {
        let data = Matrix::from_rows(&[vec![0.0, 0.0], vec![3.0, 4.0]]);
        let q = [0.0f32, 0.0];
        let arms = NnsArms::new(&data, &q, 5.0, PullOrder::Sequential, 0);
        assert!((arms.true_mean(0) - 0.0).abs() < 1e-9);
        assert!((arms.true_mean(1) + 12.5).abs() < 1e-6); // −25/2
        let (a, b) = arms.reward_range();
        assert_eq!(b, 0.0);
        assert!(a <= -25.0 + 1e-6);
    }

    #[test]
    fn exact_mode_recovers_true_neighbors() {
        let data = gaussian(80, 48, 1);
        let idx = BoundedMeNnsIndex::new(data.clone());
        let q: Vec<f32> = Rng::new(9).gaussian_vec(48);
        let res = idx.query(&q, &MipsParams { k: 3, epsilon: 1e-12, delta: 0.05, seed: 2 });
        let mut got = res.indices.clone();
        got.sort_unstable();
        let mut want = nns_ground_truth(&data, &q, 3);
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(res.flops <= 80 * 48);
    }

    #[test]
    fn distances_are_nonnegative_estimates() {
        let data = gaussian(40, 32, 3);
        let idx = BoundedMeNnsIndex::new(data);
        let q: Vec<f32> = Rng::new(4).gaussian_vec(32);
        let res = idx.query(&q, &MipsParams { k: 2, epsilon: 1e-12, delta: 0.1, seed: 1 });
        for &d in &res.distances_sq {
            assert!(d >= -1e-3, "distance² {d} negative");
        }
    }

    #[test]
    fn looser_epsilon_cheaper() {
        let data = gaussian(100, 256, 5);
        let idx = BoundedMeNnsIndex::new(data);
        let q: Vec<f32> = Rng::new(6).gaussian_vec(256);
        let tight = idx.query(&q, &MipsParams { k: 1, epsilon: 0.01, delta: 0.1, seed: 0 });
        let loose = idx.query(&q, &MipsParams { k: 1, epsilon: 0.9, delta: 0.1, seed: 0 });
        assert!(loose.flops < tight.flops);
    }

    #[test]
    fn pull_orders_agree_in_exact_mode() {
        let data = gaussian(50, 64, 7);
        let q: Vec<f32> = Rng::new(8).gaussian_vec(64);
        let want = nns_ground_truth(&data, &q, 2);
        for order in [PullOrder::Permuted, PullOrder::BlockShuffled(8), PullOrder::Sequential] {
            let idx = BoundedMeNnsIndex::with_order(data.clone(), order);
            let res =
                idx.query(&q, &MipsParams { k: 2, epsilon: 1e-12, delta: 0.05, seed: 3 });
            let mut got = res.indices.clone();
            got.sort_unstable();
            let mut w = want.clone();
            w.sort_unstable();
            assert_eq!(got, w, "{order:?}");
        }
    }
}
