//! Exhaustive (exact) MIPS: the `O(n·N)` baseline every speedup is
//! measured against.

use super::{exact_rank, MipsIndex, MipsParams, MipsResult};
use crate::data::shard::Shard;
use crate::exec::shard::ShardPartial;
use crate::exec::QueryContext;
use crate::linalg::simd::SCAN_TILE;
use crate::linalg::{dot_rows, Matrix, TopK};

/// Exact linear-scan index. No preprocessing, no error.
pub struct NaiveIndex {
    data: Matrix,
}

impl NaiveIndex {
    /// Wrap a vector set.
    pub fn new(data: Matrix) -> Self {
        Self { data }
    }

    /// Shared fused-scan core: one pass over the dataset in
    /// [`SCAN_TILE`]-row tiles, each tile scored against every query by
    /// the blocked [`dot_rows`] kernel while hot in cache — on a
    /// `B`-query batch the data is read once instead of `B` times, and
    /// each read feeds several rows per query register load.
    /// `global_id` maps scan-local row indices to the ids pushed into
    /// the per-query heaps (computed once per tile, not per query).
    fn tiled_scan(
        &self,
        queries: &[&[f32]],
        k: usize,
        global_id: impl Fn(usize) -> usize,
    ) -> Vec<TopK> {
        let (n, d) = (self.data.rows(), self.data.cols());
        let mut tops: Vec<TopK> = queries.iter().map(|_| TopK::new(k)).collect();
        let mut scores = [0f32; SCAN_TILE];
        let mut ids = [0usize; SCAN_TILE];
        let mut base = 0usize;
        while base < n {
            let take = (n - base).min(SCAN_TILE);
            let block = self.data.row_block(base, take);
            for (j, id) in ids[..take].iter_mut().enumerate() {
                *id = global_id(base + j);
            }
            for (qi, q) in queries.iter().enumerate() {
                dot_rows(block, d, q, &mut scores[..take]);
                for (j, &s) in scores[..take].iter().enumerate() {
                    tops[qi].push(s, ids[j]);
                }
            }
            base += take;
        }
        tops
    }

    /// Shard-aware batch entry point: fused scan over this index's rows
    /// (which must be `shard`'s matrix), emitting per-query top-`k`
    /// partials with **dataset-global** row ids so the cross-shard merge
    /// ([`crate::exec::shard::merge_partials`]) can run on them
    /// directly. Byte-identical scores to the unsharded scan — the rows
    /// are the same bytes (contiguous shards are views) dotted by the
    /// same kernel.
    pub fn query_batch_shard(
        &self,
        queries: &[&[f32]],
        k: usize,
        shard: &Shard,
    ) -> Vec<ShardPartial> {
        debug_assert_eq!(self.data.rows(), shard.rows(), "index/shard row mismatch");
        let tops = self.tiled_scan(queries, k, |i| shard.global_id(i));
        let (n, d) = (self.data.rows(), self.data.cols());
        tops.into_iter()
            .map(|top| ShardPartial {
                entries: top.into_sorted(),
                flops: (n * d) as u64,
                scanned: n,
            })
            .collect()
    }
}

impl MipsIndex for NaiveIndex {
    fn name(&self) -> &str {
        "Naive"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        0.0
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let (ranked, flops, candidates) =
            exact_rank(&self.data, q, 0..self.data.rows(), params.k);
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops,
            candidates,
        }
    }

    /// Scores land in the context's reusable slab instead of a fresh
    /// vector per query.
    fn query_with(&self, q: &[f32], params: &MipsParams, ctx: &mut QueryContext) -> MipsResult {
        let scores = &mut ctx.rank.scores;
        self.data.matvec_into(q, scores);
        let mut top = TopK::new(params.k);
        for (i, &s) in scores.iter().enumerate() {
            top.push(s, i);
        }
        let ranked = top.into_sorted();
        let n = self.data.rows();
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops: (n * self.data.cols()) as u64,
            candidates: n,
        }
    }

    /// Fused batch scan: the [`NaiveIndex::tiled_scan`] core with
    /// identity row ids.
    fn query_batch(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctx: &mut QueryContext,
    ) -> Vec<MipsResult> {
        let _ = ctx;
        let tops = self.tiled_scan(queries, params.k, |i| i);
        let (n, d) = (self.data.rows(), self.data.cols());
        tops.into_iter()
            .map(|top| {
                let ranked = top.into_sorted();
                MipsResult {
                    indices: ranked.iter().map(|&(_, i)| i).collect(),
                    scores: ranked.iter().map(|&(s, _)| s).collect(),
                    flops: (n * d) as u64,
                    candidates: n,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> NaiveIndex {
        NaiveIndex::new(Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![-1.0, -1.0],
            vec![3.0, 3.0],
        ]))
    }

    #[test]
    fn returns_exact_top_k_with_full_flops() {
        let idx = fixture();
        let res = idx.query(&[1.0, 1.0], &MipsParams { k: 2, ..Default::default() });
        assert_eq!(res.indices, vec![3, 0]);
        assert_eq!(res.scores, vec![6.0, 3.0]);
        assert_eq!(res.flops, 8);
        assert_eq!(res.candidates, 4);
    }

    #[test]
    fn query_with_matches_query() {
        let idx = fixture();
        let params = MipsParams { k: 3, ..Default::default() };
        let mut ctx = QueryContext::new();
        for q in [[1.0f32, 1.0], [0.5, -2.0], [-1.0, 0.0]] {
            let a = idx.query(&q, &params);
            let b = idx.query_with(&q, &params, &mut ctx);
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.scores, b.scores);
            assert_eq!(a.flops, b.flops);
        }
    }

    #[test]
    fn fused_batch_matches_singles() {
        let idx = fixture();
        let params = MipsParams { k: 2, ..Default::default() };
        let qs: Vec<Vec<f32>> = vec![vec![1.0, 1.0], vec![-1.0, 2.0], vec![0.0, -1.0]];
        let refs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let mut ctx = QueryContext::new();
        let batch = idx.query_batch(&refs, &params, &mut ctx);
        for (i, q) in qs.iter().enumerate() {
            let single = idx.query(q, &params);
            assert_eq!(batch[i].indices, single.indices, "query {i}");
            assert_eq!(batch[i].scores, single.scores, "query {i}");
        }
    }
}
