//! Exhaustive (exact) MIPS: the `O(n·N)` baseline every speedup is
//! measured against.

use super::{exact_rank, MipsIndex, MipsParams, MipsResult};
use crate::linalg::Matrix;

/// Exact linear-scan index. No preprocessing, no error.
pub struct NaiveIndex {
    data: Matrix,
}

impl NaiveIndex {
    /// Wrap a vector set.
    pub fn new(data: Matrix) -> Self {
        Self { data }
    }
}

impl MipsIndex for NaiveIndex {
    fn name(&self) -> &str {
        "Naive"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        0.0
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let (ranked, flops, candidates) =
            exact_rank(&self.data, q, 0..self.data.rows(), params.k);
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops,
            candidates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_exact_top_k_with_full_flops() {
        let data = Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![-1.0, -1.0],
            vec![3.0, 3.0],
        ]);
        let idx = NaiveIndex::new(data);
        let res = idx.query(&[1.0, 1.0], &MipsParams { k: 2, ..Default::default() });
        assert_eq!(res.indices, vec![3, 0]);
        assert_eq!(res.scores, vec![6.0, 3.0]);
        assert_eq!(res.flops, 8);
        assert_eq!(res.candidates, 4);
    }
}
