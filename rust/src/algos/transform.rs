//! The MIPS → NNS/cosine Euclidean transform of Bachrach et al. 2014.
//!
//! Scale every data vector by `1/U` (`U` = max row norm) so norms are
//! ≤ 1, then append the coordinate `√(1 − ‖v/U‖²)`; queries are
//! normalized and padded with 0. Inner products in the augmented space
//! are monotone in the original inner products, so cosine-LSH trees /
//! hyperplanes built there solve MIPS.
//!
//! We never materialize the `n × (N+1)` augmented matrix on the query
//! path: augmented projections decompose as
//! `⟨h, v*⟩ = (1/U)·⟨h[..N], v⟩ + h[N]·aug_i`.

use crate::linalg::{dot, norm, Matrix};

/// Precomputed transform state: the scale and per-item augmented
/// coordinates.
#[derive(Clone, Debug)]
pub struct EuclideanTransform {
    /// `1 / U` where `U = max_i ‖v_i‖`.
    pub inv_scale: f32,
    /// `aug[i] = √(1 − ‖v_i/U‖²)`.
    pub aug: Vec<f32>,
}

impl EuclideanTransform {
    /// Compute the transform for a vector set (`O(n·N)`, preprocessing).
    pub fn new(data: &Matrix) -> Self {
        let u = data.max_row_norm().max(f32::MIN_POSITIVE);
        let inv_scale = 1.0 / u;
        let aug = data
            .iter_rows()
            .map(|row| {
                let s = norm(row) * inv_scale;
                (1.0 - (s * s).min(1.0)).max(0.0).sqrt()
            })
            .collect();
        Self { inv_scale, aug }
    }

    /// Augmented dimension (`N + 1`).
    pub fn dim(&self, data: &Matrix) -> usize {
        data.cols() + 1
    }

    /// Project transformed item `i` onto an augmented direction
    /// `dir ∈ R^{N+1}` without materializing the transform:
    /// `(1/U)·⟨dir[..N], v_i⟩ + dir[N]·aug_i`.
    #[inline]
    pub fn project_item(&self, data: &Matrix, dir: &[f32], i: usize) -> f32 {
        debug_assert_eq!(dir.len(), data.cols() + 1);
        self.inv_scale * dot(&dir[..data.cols()], data.row(i)) + dir[data.cols()] * self.aug[i]
    }

    /// Transform a query: unit-normalize and pad with a 0 coordinate.
    pub fn transform_query(&self, q: &[f32]) -> Vec<f32> {
        let n = norm(q);
        let inv = if n > 0.0 { 1.0 / n } else { 0.0 };
        let mut out = Vec::with_capacity(q.len() + 1);
        out.extend(q.iter().map(|&x| x * inv));
        out.push(0.0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn augmented_norms_are_unit() {
        let mut rng = Rng::new(1);
        let data = Matrix::from_fn(20, 8, |_, _| rng.gaussian() as f32);
        let t = EuclideanTransform::new(&data);
        for i in 0..20 {
            let scaled_sq = crate::linalg::norm_sq(data.row(i)) * t.inv_scale * t.inv_scale;
            let total = scaled_sq + t.aug[i] * t.aug[i];
            assert!((total - 1.0).abs() < 1e-5, "item {i}: {total}");
        }
    }

    #[test]
    fn projection_matches_materialized_transform() {
        let mut rng = Rng::new(2);
        let data = Matrix::from_fn(10, 6, |_, _| rng.gaussian() as f32);
        let t = EuclideanTransform::new(&data);
        let dir: Vec<f32> = rng.gaussian_vec(7);
        for i in 0..10 {
            // Materialize v* = [v/U ; aug] and compare.
            let mut vstar: Vec<f32> = data.row(i).iter().map(|&x| x * t.inv_scale).collect();
            vstar.push(t.aug[i]);
            let expect = dot(&vstar, &dir);
            let got = t.project_item(&data, &dir, i);
            assert!((got - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn transform_preserves_mips_order_in_cosine() {
        // ⟨q*, v*⟩ = ⟨q,v⟩/(U‖q‖): same argmax as MIPS.
        let mut rng = Rng::new(3);
        let data = Matrix::from_fn(30, 12, |_, _| rng.gaussian() as f32);
        let t = EuclideanTransform::new(&data);
        let q: Vec<f32> = rng.gaussian_vec(12);
        let qs = t.transform_query(&q);
        let mips_best = crate::algos::ground_truth(&data, &q, 1)[0];
        let cos_best = (0..30)
            .max_by(|&a, &b| {
                t.project_item(&data, &qs, a)
                    .partial_cmp(&t.project_item(&data, &qs, b))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(mips_best, cos_best);
    }

    #[test]
    fn zero_query_safe() {
        let data = Matrix::from_rows(&[vec![1.0, 0.0]]);
        let t = EuclideanTransform::new(&data);
        let qs = t.transform_query(&[0.0, 0.0]);
        assert_eq!(qs, vec![0.0, 0.0, 0.0]);
    }
}
