//! MIPS algorithms behind a common [`MipsIndex`] trait.
//!
//! | index | paper | preprocessing | knob |
//! |---|---|---|---|
//! | [`NaiveIndex`] | exhaustive search | none | — |
//! | [`BoundedMeIndex`] | **this paper** | none | per-query (ε, δ) |
//! | [`GreedyMipsIndex`] | Yu et al. 2017 | per-dim sorted lists | budget `B` |
//! | [`LshMipsIndex`] | Shrivastava & Li 2014 / Neyshabur & Srebro 2015 | `b` hash tables | `(a, b)` |
//! | [`PcaMipsIndex`] | Bachrach et al. 2014 | PCA tree | depth `d` |
//! | [`RptMipsIndex`] | Keivani, Sinha & Ram 2017 | `L` random trees | `(L, leaf)` |
//!
//! All indexes account their work in **flops** (scalar multiplications on
//! the query path — the currency of the paper's cost model, where one
//! bandit pull = one multiplication) so the "online speedup" of the
//! figures is `flops(naive) / flops(algo)`, plus wall-clock timing.

pub mod bounded_me_index;
pub mod greedy;
pub mod hull;
pub mod lsh;
pub mod naive;
pub mod nns;
pub mod pca_mips;
pub mod rpt;
pub mod transform;

pub use bounded_me_index::BoundedMeIndex;
pub use greedy::GreedyMipsIndex;
pub use hull::BoundedMeHullIndex;
pub use lsh::LshMipsIndex;
pub use naive::NaiveIndex;
pub use nns::BoundedMeNnsIndex;
pub use pca_mips::PcaMipsIndex;
pub use rpt::RptMipsIndex;

use crate::exec::QueryContext;
use crate::linalg::{dot, Matrix, TopK};

/// Per-query parameters shared by every index.
///
/// `epsilon`/`delta` are honored only by [`BoundedMeIndex`] (the other
/// algorithms have no suboptimality knob — that is Motivation II of the
/// paper); the rest use their constructor-time parameters.
#[derive(Clone, Copy, Debug)]
pub struct MipsParams {
    /// Number of results to return.
    pub k: usize,
    /// BOUNDEDME suboptimality budget ε, **relative to the reward
    /// range**: the guarantee is `(p* − p̂) ≤ ε·(b−a)` on mean rewards
    /// `qᵀv/N`, matching the paper's `[0,1]`-normalized setting where
    /// `b−a = 1` and `ε ∈ (0,1)`.
    pub epsilon: f64,
    /// BOUNDEDME failure probability δ.
    pub delta: f64,
    /// Seed for any per-query randomness (pull order, …).
    pub seed: u64,
}

impl Default for MipsParams {
    fn default() -> Self {
        Self { k: 10, epsilon: 0.1, delta: 0.1, seed: 0 }
    }
}

/// Result of one MIPS query.
#[derive(Clone, Debug)]
pub struct MipsResult {
    /// Indices of the returned vectors, best-first.
    pub indices: Vec<usize>,
    /// The algorithm's score estimate for each returned vector. For
    /// candidate-ranking algorithms these are exact inner products; for
    /// BOUNDEDME they are the (possibly partial) empirical estimates
    /// `N·p̂`.
    pub scores: Vec<f32>,
    /// Scalar multiplications spent on this query.
    pub flops: u64,
    /// Size of the candidate set that was exactly ranked (0 for
    /// algorithms that do not rank candidates).
    pub candidates: usize,
}

/// A MIPS search index over a fixed vector set.
pub trait MipsIndex: Send + Sync {
    /// Short identifier used in experiment tables ("BoundedME", "LSH", …).
    fn name(&self) -> &str;
    /// The indexed vector set.
    fn data(&self) -> &Matrix;
    /// Wall-clock seconds spent building the index (0 for
    /// preprocessing-free methods).
    fn preprocessing_seconds(&self) -> f64;
    /// Answer a top-K query (one-shot: allocates any scratch it needs).
    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult;

    /// Answer a top-K query borrowing scratch from a reusable
    /// [`QueryContext`] — the zero-allocation serving path. Results are
    /// identical to [`MipsIndex::query`] for the same `params`; only
    /// the allocation behavior differs. The default ignores the context
    /// and delegates to `query`; indexes with a real hot path
    /// ([`BoundedMeIndex`], [`NaiveIndex`]) override it.
    fn query_with(&self, q: &[f32], params: &MipsParams, ctx: &mut QueryContext) -> MipsResult {
        let _ = ctx;
        self.query(q, params)
    }

    /// Answer a whole batch of queries with shared `params`, fusing
    /// whatever work can be shared (one coordinate permutation for the
    /// batch, one pass over the data, one scoring slab). The default
    /// loops [`MipsIndex::query_with`] over the batch — already sharing
    /// the context's cached pull order; fused implementations
    /// ([`NaiveIndex`]) go further.
    fn query_batch(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctx: &mut QueryContext,
    ) -> Vec<MipsResult> {
        queries.iter().map(|q| self.query_with(q, params, ctx)).collect()
    }
}

/// Exactly rank a candidate set by true inner product and keep the top
/// `k`. Returns the result and the flops spent (`|candidates| · N`).
pub(crate) fn exact_rank(
    data: &Matrix,
    q: &[f32],
    candidates: impl IntoIterator<Item = usize>,
    k: usize,
) -> (Vec<(f32, usize)>, u64, usize) {
    let mut top = TopK::new(k);
    let mut count = 0usize;
    for id in candidates {
        top.push(dot(data.row(id), q), id);
        count += 1;
    }
    let flops = (count * data.cols()) as u64;
    (top.into_sorted(), flops, count)
}

/// Ground truth: exact top-K by exhaustive search (used by the metrics
/// and tests; identical to [`NaiveIndex`] without the trait overhead).
pub fn ground_truth(data: &Matrix, q: &[f32], k: usize) -> Vec<usize> {
    let (ranked, _, _) = exact_rank(data, q, 0..data.rows(), k);
    ranked.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_rank_counts_flops() {
        let m = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]]);
        let (ranked, flops, count) = exact_rank(&m, &[1.0, 1.0], vec![0, 2], 1);
        assert_eq!(ranked[0].1, 2);
        assert_eq!(flops, 4);
        assert_eq!(count, 2);
    }

    #[test]
    fn ground_truth_is_exact() {
        let m = Matrix::from_rows(&[
            vec![1.0, 0.0],
            vec![3.0, 0.0],
            vec![2.0, 0.0],
            vec![-5.0, 0.0],
        ]);
        assert_eq!(ground_truth(&m, &[1.0, 0.0], 2), vec![1, 2]);
    }
}
