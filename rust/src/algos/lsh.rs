//! LSH-MIPS (Shrivastava & Li 2014; Neyshabur & Srebro 2015).
//!
//! MIPS is reduced to cosine similarity search via the Euclidean
//! transform ([`super::transform`]), then answered with sign-random-
//! projection LSH: `b` hash tables (OR-construction), each keyed by an
//! `a`-bit code of hyperplane signs (AND-construction). Candidates are
//! the union of the query's buckets, ranked exactly.
//!
//! The `(a, b)` pair is the accuracy knob; the success probability
//! depends on the (unknown) angle of the true answer, so the user cannot
//! bound suboptimality a priori — the contrast drawn in Table 1.

use super::transform::EuclideanTransform;
use super::{exact_rank, MipsIndex, MipsParams, MipsResult};
use crate::linalg::{Matrix, Rng};
use std::collections::HashMap;
use std::time::Instant;

/// One hash table: `a` hyperplanes and the bucket map.
struct Table {
    /// `a × (N+1)` hyperplane directions, row-major.
    planes: Vec<f32>,
    buckets: HashMap<u64, Vec<u32>>,
}

/// LSH-MIPS index.
pub struct LshMipsIndex {
    data: Matrix,
    transform: EuclideanTransform,
    tables: Vec<Table>,
    bits: usize,
    prep_seconds: f64,
}

impl LshMipsIndex {
    /// Build `b` tables of `a`-bit signed-random-projection codes
    /// (`a ≤ 64`). Preprocessing is `O(N·n·a·b)`.
    pub fn new(data: Matrix, a: usize, b: usize, seed: u64) -> Self {
        assert!((1..=64).contains(&a), "a must be in 1..=64");
        assert!(b >= 1, "b must be ≥ 1");
        let t0 = Instant::now();
        let transform = EuclideanTransform::new(&data);
        let dim = data.cols() + 1;
        let mut rng = Rng::new(seed);
        let n = data.rows();
        let mut tables = Vec::with_capacity(b);
        for _ in 0..b {
            let planes: Vec<f32> = rng.gaussian_vec(a * dim);
            let mut buckets: HashMap<u64, Vec<u32>> = HashMap::new();
            for i in 0..n {
                let mut code = 0u64;
                for h in 0..a {
                    let dir = &planes[h * dim..(h + 1) * dim];
                    if transform.project_item(&data, dir, i) >= 0.0 {
                        code |= 1 << h;
                    }
                }
                buckets.entry(code).or_default().push(i as u32);
            }
            tables.push(Table { planes, buckets });
        }
        let prep_seconds = t0.elapsed().as_secs_f64();
        Self { data, transform, tables, bits: a, prep_seconds }
    }

    /// Number of bits per code (`a`).
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Number of tables (`b`).
    pub fn n_tables(&self) -> usize {
        self.tables.len()
    }
}

impl MipsIndex for LshMipsIndex {
    fn name(&self) -> &str {
        "LSH"
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        self.prep_seconds
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        let qs = self.transform.transform_query(q);
        let dim = qs.len();
        let mut flops = q.len() as u64; // query normalization
        let mut visited = vec![false; self.data.rows()];
        let mut candidates = Vec::new();
        for table in &self.tables {
            let mut code = 0u64;
            for h in 0..self.bits {
                let dir = &table.planes[h * dim..(h + 1) * dim];
                if crate::linalg::dot(dir, &qs) >= 0.0 {
                    code |= 1 << h;
                }
            }
            flops += (self.bits * dim) as u64;
            if let Some(bucket) = table.buckets.get(&code) {
                for &i in bucket {
                    if !visited[i as usize] {
                        visited[i as usize] = true;
                        candidates.push(i as usize);
                    }
                }
            }
        }
        let (ranked, rank_flops, cand_count) =
            exact_rank(&self.data, q, candidates, params.k);
        MipsResult {
            indices: ranked.iter().map(|&(_, i)| i).collect(),
            scores: ranked.iter().map(|&(s, _)| s).collect(),
            flops: flops + rank_flops,
            candidates: cand_count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ground_truth;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn generous_tables_find_the_answer() {
        let data = gaussian(150, 24, 1);
        // Few bits + many tables ⇒ high recall.
        let idx = LshMipsIndex::new(data.clone(), 4, 24, 7);
        let mut hits = 0;
        for qs in 0..10u64 {
            let q: Vec<f32> = Rng::new(100 + qs).gaussian_vec(24);
            let res = idx.query(&q, &MipsParams { k: 1, ..Default::default() });
            if !res.indices.is_empty() && res.indices[0] == ground_truth(&data, &q, 1)[0] {
                hits += 1;
            }
        }
        assert!(hits >= 7, "recall {hits}/10 too low");
    }

    #[test]
    fn more_bits_fewer_candidates() {
        let data = gaussian(400, 16, 2);
        let coarse = LshMipsIndex::new(data.clone(), 2, 4, 3);
        let fine = LshMipsIndex::new(data, 12, 4, 3);
        let q: Vec<f32> = Rng::new(5).gaussian_vec(16);
        let p = MipsParams { k: 1, ..Default::default() };
        let rc = coarse.query(&q, &p);
        let rf = fine.query(&q, &p);
        assert!(rf.candidates < rc.candidates, "{} !< {}", rf.candidates, rc.candidates);
    }

    #[test]
    fn empty_buckets_return_empty() {
        // A single far-away point with aggressive bits can miss; the
        // result must be well-formed either way.
        let data = gaussian(5, 8, 4);
        let idx = LshMipsIndex::new(data, 16, 1, 9);
        let q: Vec<f32> = Rng::new(6).gaussian_vec(8);
        let res = idx.query(&q, &MipsParams { k: 3, ..Default::default() });
        assert!(res.indices.len() <= 3);
        assert_eq!(res.indices.len(), res.scores.len());
    }

    #[test]
    fn accessors() {
        let idx = LshMipsIndex::new(gaussian(10, 4, 5), 6, 3, 1);
        assert_eq!(idx.bits(), 6);
        assert_eq!(idx.n_tables(), 3);
        assert!(idx.preprocessing_seconds() > 0.0);
    }

    #[test]
    #[should_panic]
    fn rejects_too_many_bits() {
        LshMipsIndex::new(gaussian(4, 4, 1), 65, 1, 0);
    }
}
