//! BOUNDEDME as a [`MipsIndex`]: the paper's contribution on the MIPS
//! interface. Zero preprocessing; per-query (ε, δ, K) knobs.

use super::{MipsIndex, MipsParams, MipsResult};
use crate::bandit::{
    AnytimeBudget, BoundedMe, BoundedMeConfig, Compaction, Harvest, MatrixArms, PullOrder,
    QuantArms, RewardSource,
};
use crate::data::quant::{QuantMatrix, Storage};
use crate::data::shard::Shard;
use crate::exec::shard::ShardPartial;
use crate::exec::QueryContext;
use crate::linalg::{partial_dot_rows_chunked, Matrix};
use crate::trace::QueryExec;
use std::time::Instant;

/// Preprocessing-free MIPS with a suboptimality guarantee: for any query
/// and user-chosen `0 < ε, δ < 1`, the returned set is ε-optimal (in
/// mean-reward units, `qᵀv/N`) with probability ≥ 1 − δ.
///
/// # The two-tier sample-then-confirm path ([`Self::with_storage`])
///
/// With a compressed [`Storage`] tier attached, a query *samples* from
/// the f16/bf16/int8 codes (2–4× fewer bytes per pull) and *confirms*
/// the surviving arms with exact f32 inner products. The (ε, δ)
/// guarantee is preserved against the **true** f32 means by splitting
/// the ε budget: quantization perturbs every arm's mean by at most
/// `b = max_row_err · ‖q‖₁ / N` (the per-row error bound recorded at
/// [`QuantMatrix::quantize`] time), so running the bandit at
/// `ε' = ε·range − 2b` on dequantized means makes the returned set
/// `(ε·range)`-optimal under true means. When the budget doesn't cover
/// the noise (`ε' ≤ 0`, e.g. ε → 0 exact queries), the query silently
/// drops to the f32 tier — exactness is never sacrificed. The
/// `RUST_PALLAS_FORCE_F32` hatch disables the compressed tier globally,
/// making every query bit-identical to an index built without
/// [`Self::with_storage`].
pub struct BoundedMeIndex {
    data: Matrix,
    /// Compressed sampling tier (present iff `storage != F32`): the
    /// same rows as `data`, re-coded, with recorded quantization error.
    quant: Option<QuantMatrix>,
    /// Effective storage of the sampling tier (after the
    /// `RUST_PALLAS_FORCE_F32` hatch is applied at build time).
    storage: Storage,
    /// Per-coordinate maxima `colmax[j] = max_i |v_i^(j)|`. The only
    /// dataset-wide metadata the method needs: one streaming scan at
    /// load time, no data structure — keeping the paper's "zero
    /// preprocessing" property in spirit and in wall-clock. Per query
    /// the reward bound is `b = max_j colmax[j]·|q_j|`, much tighter
    /// than the global `max|v|·max|q|`.
    colmax: Vec<f32>,
    order: PullOrder,
    /// Survivor-compaction policy for the elimination core (layout
    /// only: results are bit-identical across policies). Defaults to
    /// the serving policy — compact once the survivor fraction drops
    /// to [`Compaction::DEFAULT_FRACTION`] — unless
    /// `RUST_PALLAS_FORCE_NO_COMPACT` pins the scattered layout.
    compaction: Compaction,
}

impl BoundedMeIndex {
    /// Build over a vector set with the default (fully permuted) pull
    /// order.
    pub fn new(data: Matrix) -> Self {
        Self::with_order(data, PullOrder::Permuted)
    }

    /// Build with an explicit pull order (see [`PullOrder`]; the
    /// block-shuffled order is the cache-friendly serving default).
    pub fn with_order(data: Matrix, order: PullOrder) -> Self {
        let colmax = column_maxima(&data);
        Self {
            data,
            quant: None,
            storage: Storage::F32,
            colmax,
            order,
            compaction: Compaction::default(),
        }
    }

    /// Attach a compressed sampling tier (see the struct docs for the
    /// two-tier query path). `Storage::F32` (or any request under the
    /// `RUST_PALLAS_FORCE_F32` hatch) is a no-op: queries stay on the
    /// exact tier and are bit-identical to an unadorned index.
    pub fn with_storage(mut self, storage: Storage) -> Self {
        let eff = storage.effective();
        self.quant =
            (eff != Storage::F32).then(|| QuantMatrix::quantize(&self.data, eff));
        self.storage = eff;
        self
    }

    /// The effective storage tier queries sample from ([`Storage::F32`]
    /// unless [`Self::with_storage`] attached a compressed tier).
    pub fn storage(&self) -> Storage {
        self.storage
    }

    /// Override the survivor-compaction policy (see [`Compaction`]);
    /// panics here — at index construction — on an out-of-range
    /// fraction, not on the first query.
    pub fn with_compaction(mut self, compaction: Compaction) -> Self {
        self.compaction = compaction.validated();
        self
    }

    /// The dataset's largest |coordinate| (coarse reward-range input).
    pub fn max_abs_coord(&self) -> f32 {
        self.colmax.iter().fold(f32::MIN_POSITIVE, |m, &x| m.max(x))
    }

    /// Shard-aware batch entry point: the **sample-then-confirm** step
    /// of sharded BOUNDEDME. `params` must already be the per-shard
    /// split from [`crate::exec::shard::shard_params`] — `(k_s, ε,
    /// δ/S)` — and this index must be built over `shard`'s matrix.
    ///
    /// Per query: run the bandit over the shard's rows (the *sample*
    /// step, sharing one cached pull order across the batch like
    /// [`MipsIndex::query_batch`]), then exactly rescore the ≤ `k_s`
    /// surviving candidates (the *confirm* step — row-local, `k_s · N`
    /// flops) so the emitted partial carries true inner products under
    /// **dataset-global** ids. The cross-shard merge can then rank on
    /// exact scores, which is what lets the per-shard ε pass through
    /// unsplit (see [`crate::exec::shard`] module docs).
    pub fn query_batch_shard(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctx: &mut QueryContext,
        shard: &Shard,
    ) -> Vec<ShardPartial> {
        self.query_batch_shard_tier(queries, params, ctx, shard, self.storage)
    }

    /// [`Self::query_batch_shard`] with an explicit **resolved** sampling
    /// tier (see [`crate::coordinator::resolve_storage`]): the
    /// deployment's own tier behaves identically to the plain entry
    /// point; [`Storage::F32`] on a compressed deployment opts the
    /// queries out of the compressed codes for this call only.
    pub fn query_batch_shard_tier(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctx: &mut QueryContext,
        shard: &Shard,
        tier: Storage,
    ) -> Vec<ShardPartial> {
        queries
            .iter()
            .map(|q| self.query_shard_tier_budget(q, params, ctx, shard, tier, AnytimeBudget::NONE).0)
            .collect()
    }

    /// Single-query form of [`Self::query_batch_shard_tier`] that also
    /// threads an [`AnytimeBudget`] through the bandit. With
    /// [`AnytimeBudget::NONE`] this is bit-identical to one iteration of
    /// the batch entry point (which delegates here); with an armed
    /// budget the *sample* step may stop early at a round checkpoint, in
    /// which case the harvested survivors still go through the exact
    /// confirm rescore and the returned [`Harvest`] carries the achieved
    /// ε̂ in the same request-relative units as [`MipsParams::epsilon`].
    pub fn query_shard_tier_budget(
        &self,
        q: &[f32],
        params: &MipsParams,
        ctx: &mut QueryContext,
        shard: &Shard,
        tier: Storage,
        budget: AnytimeBudget,
    ) -> (ShardPartial, Option<Harvest>) {
        debug_assert_eq!(self.data.rows(), shard.rows(), "index/shard row mismatch");
        let dim = self.data.cols();
        let (res, harvest) = self.query_with_tier_budget(q, params, ctx, tier, budget);
        let confirm_t0 = if ctx.trace.armed { Some(Instant::now()) } else { None };
        // Confirm step as blocked kernels: survivors are scattered rows,
        // scored through the shared `partial_dot_rows` staging loop
        // (bit-identical per row to `dot`), several candidates per query
        // register load.
        let mut entries: Vec<(f32, usize)> = Vec::with_capacity(res.indices.len());
        partial_dot_rows_chunked(
            res.indices.iter().map(|&local| self.data.row(local)),
            q,
            |i, score| entries.push((score, shard.global_id(res.indices[i]))),
        );
        if let Some(t0) = confirm_t0 {
            if let Some(exec) = ctx.trace.queries.last_mut() {
                exec.confirm_ns += t0.elapsed().as_nanos() as u64;
                exec.ended = Instant::now();
            }
        }
        let confirm_flops = (entries.len() * dim) as u64;
        (
            ShardPartial {
                flops: res.flops + confirm_flops,
                scanned: entries.len(),
                entries,
            },
            harvest,
        )
    }

    /// The per-query reward bound `b = max_j colmax[j]·|q_j|`.
    pub fn reward_bound(&self, q: &[f32]) -> f32 {
        self.colmax
            .iter()
            .zip(q)
            .fold(f32::MIN_POSITIVE, |m, (&c, &qj)| m.max(c * qj.abs()))
    }

    /// The compressed-tier query path: sample from the quantized codes,
    /// confirm survivors exactly on f32. Returns `None` — caller falls
    /// through to the f32 tier — when no compressed tier is attached or
    /// the ε budget can't absorb the quantization bias.
    fn query_quant(
        &self,
        q: &[f32],
        params: &MipsParams,
        ctx: &mut QueryContext,
        budget: AnytimeBudget,
    ) -> Option<(MipsResult, Option<Harvest>)> {
        let qm = self.quant.as_ref()?;
        let n_list = self.data.cols() as f64;
        // ε is range-relative against the *f32* tier (the guarantee is
        // stated on true means), so the absolute target comes from the
        // f32 reward range `±reward_bound` — the same `ε · range_width`
        // the f32 path computes through `MatrixArms::range_width`.
        let eff_target =
            params.epsilon * 2.0 * self.reward_bound(q).max(f32::MIN_POSITIVE) as f64;
        // Quantization shifts every arm's mean by at most
        // b = max_row_err · ‖q‖₁ / N; an ε'-optimal set under the
        // dequantized means is (ε' + 2b)-optimal under true means, so
        // spend ε' = target − 2b on the bandit.
        let l1: f64 = q.iter().map(|&x| x.abs() as f64).sum();
        let bias = qm.max_err() as f64 * l1 / n_list;
        let eff_eps_q = eff_target - 2.0 * bias;
        if eff_eps_q <= 0.0 {
            // A tier is present but ε can't absorb the bias; flag the
            // fallback so the f32 run the caller drops to records it.
            if ctx.trace.armed {
                ctx.trace.quant_fallback = true;
            }
            return None;
        }
        // Dequantized rewards need their own bound: the codes' colmax
        // can exceed the f32 colmax by up to the quantization error.
        let qbound = qm
            .colmax()
            .iter()
            .zip(q)
            .fold(f32::MIN_POSITIVE, |m, (&c, &qj)| m.max(c * qj.abs()));
        let QueryContext { pull, bandit, trace, .. } = ctx;
        pull.prepare(self.order, self.data.cols(), params.seed);
        pull.gather(q);
        let arms = QuantArms::with_scratch(qm, qbound, pull);
        let algo = BoundedMe::new(BoundedMeConfig {
            k: params.k.max(1),
            epsilon: eff_eps_q.max(f64::MIN_POSITIVE),
            delta: params.delta.clamp(f64::MIN_POSITIVE, 1.0 - 1e-12),
        })
        .with_compaction(self.compaction);
        let out = if trace.armed {
            let mut exec = QueryExec::begin();
            exec.quant = true;
            let out = algo.run_in_traced_budget(&arms, bandit, Some(&mut exec.rounds), budget);
            exec.total_pulls = out.total_pulls;
            exec.bandit_ns = exec.started.elapsed().as_nanos() as u64;
            trace.queries.push(exec);
            out
        } else {
            algo.run_in_budget(&arms, bandit, budget)
        };
        // An ε̂'-optimal harvest under dequantized means is
        // (ε̂' + 2b)-optimal under true means (same argument as the ε
        // split above); convert back to request-relative units.
        let harvest = bandit.last_harvest().map(|h| Harvest {
            epsilon_hat: (h.epsilon_hat + 2.0 * bias) / eff_target * params.epsilon,
            rounds: h.rounds,
        });
        if let (Some(h), true) = (harvest, trace.armed) {
            if let Some(exec) = trace.queries.last_mut() {
                exec.harvest = Some(h.epsilon_hat);
            }
        }
        let confirm_t0 = if trace.armed { Some(Instant::now()) } else { None };
        // Confirm step: exact f32 rescore of the ≤ k survivors through
        // the shared blocked staging loop (bit-identical per row to
        // `dot`), then re-rank on exact scores (ties broken by id so
        // the ordering is deterministic).
        let mut entries: Vec<(f32, usize)> = Vec::with_capacity(out.arms.len());
        partial_dot_rows_chunked(
            out.arms.iter().map(|&arm| self.data.row(arm)),
            q,
            |i, score| entries.push((score, out.arms[i])),
        );
        entries.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        if let Some(t0) = confirm_t0 {
            if let Some(exec) = trace.queries.last_mut() {
                exec.confirm_ns = t0.elapsed().as_nanos() as u64;
                exec.ended = Instant::now();
            }
        }
        let confirm_flops = (entries.len() * self.data.cols()) as u64;
        Some((
            MipsResult {
                indices: entries.iter().map(|&(_, id)| id).collect(),
                scores: entries.iter().map(|&(s, _)| s).collect(),
                flops: out.total_pulls + confirm_flops,
                candidates: 0,
            },
            harvest,
        ))
    }

    /// [`MipsIndex::query_with`] with an explicit **resolved** sampling
    /// tier. The coordinator resolves a per-request [`Storage`] override
    /// (see [`crate::coordinator::resolve_storage`]) to either this
    /// index's own tier — identical to [`MipsIndex::query_with`] — or
    /// [`Storage::F32`], which skips the compressed codes entirely for
    /// this query (a deliberate opt-out, distinct from the ε-bias
    /// fallback inside [`Self::query_quant`], so no `quant_fallback`
    /// flag is raised).
    pub fn query_with_tier(
        &self,
        q: &[f32],
        params: &MipsParams,
        ctx: &mut QueryContext,
        tier: Storage,
    ) -> MipsResult {
        self.query_with_tier_budget(q, params, ctx, tier, AnytimeBudget::NONE).0
    }

    /// [`Self::query_with_tier`] with an [`AnytimeBudget`] threaded
    /// through to the elimination core. With [`AnytimeBudget::NONE`]
    /// (or under `RUST_PALLAS_FORCE_NO_DEGRADE`) the result is
    /// bit-identical to the plain entry point and the harvest slot is
    /// `None`. When the budget expires mid-run, the best-so-far round
    /// checkpoint is returned instead and the [`Harvest`] reports the
    /// achieved confidence width ε̂ **in the same request-relative
    /// units as [`MipsParams::epsilon`]** (converted from the config
    /// units the bandit ran at: divided by the f32 reward-range width
    /// on the exact tier, bias-inflated then normalized on the
    /// compressed tier) plus the number of completed rounds.
    pub fn query_with_tier_budget(
        &self,
        q: &[f32],
        params: &MipsParams,
        ctx: &mut QueryContext,
        tier: Storage,
        budget: AnytimeBudget,
    ) -> (MipsResult, Option<Harvest>) {
        if tier == self.storage {
            if let Some(res) = self.query_quant(q, params, ctx, budget) {
                return res;
            }
        }
        self.query_f32(q, params, ctx, budget)
    }

    /// [`MipsIndex::query_batch`] with an explicit resolved sampling
    /// tier (shares one pull permutation across the batch exactly like
    /// the trait entry point).
    pub fn query_batch_tier(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctx: &mut QueryContext,
        tier: Storage,
    ) -> Vec<MipsResult> {
        let mut out = Vec::with_capacity(queries.len());
        for q in queries {
            out.push(self.query_with_tier(q, params, ctx, tier));
        }
        out
    }

    /// The exact f32 tier: the zero-allocation elimination hot path.
    /// Pull order and gathered query live in `ctx.pull` (rebuilt only
    /// when `(order, dim, seed)` changes, so a batch with one seed
    /// shares one permutation), survivor state — including the
    /// survivor-compacted pull panel — in `ctx.bandit`.
    fn query_f32(
        &self,
        q: &[f32],
        params: &MipsParams,
        ctx: &mut QueryContext,
        budget: AnytimeBudget,
    ) -> (MipsResult, Option<Harvest>) {
        let bound = self.reward_bound(q);
        // Disjoint field borrows: `pull` is held immutably by the arms
        // while `bandit` is mutated by the run (and `trace` is staged
        // independently of both).
        let QueryContext { pull, bandit, trace, .. } = ctx;
        pull.prepare(self.order, self.data.cols(), params.seed);
        pull.gather(q);
        let arms = MatrixArms::with_scratch(&self.data, bound, pull);
        let n_list = arms.list_len() as f64;
        // `params.epsilon` is range-relative (paper normalization: rewards
        // in [0,1] ⇒ ε is a fraction of the reward range). MIPS rewards
        // span ±max|v|·max|q|, so scale ε by the actual range width.
        let eff_epsilon = params.epsilon * arms.range_width();
        let algo = BoundedMe::new(BoundedMeConfig {
            k: params.k.max(1),
            epsilon: eff_epsilon.max(f64::MIN_POSITIVE),
            delta: params.delta.clamp(f64::MIN_POSITIVE, 1.0 - 1e-12),
        })
        .with_compaction(self.compaction);
        let range_width = arms.range_width();
        let out = if trace.armed {
            let mut exec = QueryExec::begin();
            // Set when a compressed tier bailed on the ε-bias just
            // before this f32 run (see `query_quant`).
            exec.quant_fallback = std::mem::take(&mut trace.quant_fallback);
            let out = algo.run_in_traced_budget(&arms, bandit, Some(&mut exec.rounds), budget);
            exec.total_pulls = out.total_pulls;
            exec.bandit_ns = exec.started.elapsed().as_nanos() as u64;
            exec.ended = Instant::now();
            trace.queries.push(exec);
            out
        } else {
            algo.run_in_budget(&arms, bandit, budget)
        };
        // ε̂ comes back in config units (ε · range) — divide the range
        // width back out so callers see request-relative units.
        let harvest = bandit.last_harvest().map(|h| Harvest {
            epsilon_hat: h.epsilon_hat / range_width.max(f64::MIN_POSITIVE),
            rounds: h.rounds,
        });
        if let (Some(h), true) = (harvest, trace.armed) {
            if let Some(exec) = trace.queries.last_mut() {
                exec.harvest = Some(h.epsilon_hat);
            }
        }
        (
            MipsResult {
                indices: out.arms,
                // Empirical mean × N ≈ inner product estimate.
                scores: out.means.iter().map(|&m| (m * n_list) as f32).collect(),
                flops: out.total_pulls,
                candidates: 0,
            },
            harvest,
        )
    }
}

/// `colmax[j] = max_i |v_i^(j)|` over the dataset (one scan).
pub fn column_maxima(data: &Matrix) -> Vec<f32> {
    let mut colmax = vec![f32::MIN_POSITIVE; data.cols()];
    for row in data.iter_rows() {
        for (m, &x) in colmax.iter_mut().zip(row) {
            *m = m.max(x.abs());
        }
    }
    colmax
}

impl MipsIndex for BoundedMeIndex {
    fn name(&self) -> &str {
        match self.order {
            PullOrder::Permuted => "BoundedME",
            PullOrder::BlockShuffled(_) => "BoundedME(block)",
            PullOrder::Sequential => "BoundedME(seq)",
        }
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn preprocessing_seconds(&self) -> f64 {
        0.0
    }

    fn query(&self, q: &[f32], params: &MipsParams) -> MipsResult {
        self.query_with(q, params, &mut QueryContext::new())
    }

    /// The zero-allocation hot path (see [`Self::query_f32`] for the
    /// scratch discipline): compressed tier first (a no-op without
    /// [`Self::with_storage`]), falling through to the exact f32 tier
    /// when the ε budget can't absorb the quantization bias. Equivalent
    /// to [`Self::query_with_tier`] at the index's own tier.
    fn query_with(&self, q: &[f32], params: &MipsParams, ctx: &mut QueryContext) -> MipsResult {
        self.query_with_tier(q, params, ctx, self.storage)
    }

    /// Batched execution: all queries share `params` (including the
    /// seed), so [`crate::bandit::PullScratch::prepare`] builds the
    /// block-shuffled permutation once and every query only re-gathers
    /// its own values — the "one permutation per batch" contract the
    /// coordinator relies on.
    fn query_batch(
        &self,
        queries: &[&[f32]],
        params: &MipsParams,
        ctx: &mut QueryContext,
    ) -> Vec<MipsResult> {
        self.query_batch_tier(queries, params, ctx, self.storage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::ground_truth;
    use crate::linalg::Rng;

    fn gaussian(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn small_epsilon_recovers_exact_top_k() {
        let data = gaussian(80, 64, 1);
        let idx = BoundedMeIndex::new(data.clone());
        let q: Vec<f32> = Rng::new(99).gaussian_vec(64);
        let res = idx.query(
            &q,
            &MipsParams { k: 3, epsilon: 1e-9, delta: 0.05, seed: 7 },
        );
        // ε → 0 forces t_l = N: elimination on exact means ⇒ exact answer.
        let truth = ground_truth(&data, &q, 3);
        let mut got = res.indices.clone();
        got.sort_unstable();
        let mut want = truth.clone();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn flops_never_exceed_exhaustive() {
        let data = gaussian(64, 50, 2);
        let idx = BoundedMeIndex::new(data);
        let q: Vec<f32> = Rng::new(3).gaussian_vec(50);
        for eps in [1e-9, 0.01, 0.1, 0.5] {
            let res = idx.query(&q, &MipsParams { k: 1, epsilon: eps, delta: 0.1, seed: 1 });
            assert!(res.flops <= 64 * 50, "eps={eps}: flops={}", res.flops);
        }
    }

    #[test]
    fn larger_epsilon_fewer_flops() {
        let data = gaussian(128, 256, 4);
        let idx = BoundedMeIndex::new(data);
        let q: Vec<f32> = Rng::new(5).gaussian_vec(256);
        let tight = idx.query(&q, &MipsParams { k: 1, epsilon: 0.01, delta: 0.1, seed: 1 });
        let loose = idx.query(&q, &MipsParams { k: 1, epsilon: 0.8, delta: 0.1, seed: 1 });
        assert!(loose.flops < tight.flops, "{} !< {}", loose.flops, tight.flops);
    }

    #[test]
    fn block_order_matches_quality() {
        let data = gaussian(100, 128, 6);
        let idx = BoundedMeIndex::with_order(data.clone(), PullOrder::BlockShuffled(16));
        assert_eq!(idx.name(), "BoundedME(block)");
        let q: Vec<f32> = Rng::new(7).gaussian_vec(128);
        let res = idx.query(&q, &MipsParams { k: 1, epsilon: 1e-9, delta: 0.1, seed: 2 });
        assert_eq!(res.indices, ground_truth(&data, &q, 1));
    }

    #[test]
    fn zero_preprocessing() {
        let idx = BoundedMeIndex::new(gaussian(10, 10, 8));
        assert_eq!(idx.preprocessing_seconds(), 0.0);
    }

    #[test]
    #[should_panic]
    fn bad_compaction_fraction_fails_at_construction() {
        // The invalid policy must panic in the builder, not on the
        // first query.
        let _ = BoundedMeIndex::new(gaussian(10, 10, 8))
            .with_compaction(Compaction::AtFraction(2.0));
    }

    #[test]
    fn reused_context_is_bit_identical_to_fresh() {
        let data = gaussian(120, 256, 9);
        let idx = BoundedMeIndex::with_order(data, PullOrder::BlockShuffled(32));
        let mut ctx = QueryContext::new();
        for seed in 0..6u64 {
            let q: Vec<f32> = Rng::new(100 + seed).gaussian_vec(256);
            let params = MipsParams { k: 4, epsilon: 0.1, delta: 0.1, seed };
            let fresh = idx.query(&q, &params);
            let reused = idx.query_with(&q, &params, &mut ctx);
            assert_eq!(fresh.indices, reused.indices, "seed={seed}");
            assert_eq!(fresh.flops, reused.flops, "seed={seed}");
            for (a, b) in fresh.scores.iter().zip(&reused.scores) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed={seed}");
            }
        }
    }

    #[test]
    fn query_batch_matches_per_query() {
        let data = gaussian(90, 128, 10);
        let idx = BoundedMeIndex::with_order(data, PullOrder::BlockShuffled(16));
        let qs: Vec<Vec<f32>> = (0..8).map(|i| Rng::new(200 + i).gaussian_vec(128)).collect();
        let refs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let params = MipsParams { k: 3, epsilon: 0.08, delta: 0.1, seed: 5 };
        let mut ctx = QueryContext::new();
        let batch = idx.query_batch(&refs, &params, &mut ctx);
        assert_eq!(batch.len(), 8);
        for (i, q) in qs.iter().enumerate() {
            let single = idx.query(q, &params);
            assert_eq!(batch[i].indices, single.indices, "query {i}");
            assert_eq!(batch[i].flops, single.flops, "query {i}");
        }
    }

    #[test]
    fn shard_entry_point_confirms_with_global_ids() {
        use crate::data::shard::{ShardSpec, ShardedMatrix};
        let data = gaussian(40, 64, 12);
        let sm = ShardedMatrix::new(data.clone(), ShardSpec::contiguous(2));
        let shard = sm.shard(1); // rows 20..40
        let idx =
            BoundedMeIndex::with_order(shard.matrix().clone(), PullOrder::BlockShuffled(16));
        let q: Vec<f32> = Rng::new(77).gaussian_vec(64);
        let mut ctx = QueryContext::new();
        let params = MipsParams { k: 3, epsilon: 1e-9, delta: 0.05, seed: 1 };
        let partials = idx.query_batch_shard(&[&q[..]], &params, &mut ctx, shard);
        let partial = &partials[0];
        assert_eq!(partial.entries.len(), 3);
        assert_eq!(partial.scanned, 3);
        for &(score, gid) in &partial.entries {
            assert!((20..40).contains(&gid), "id {gid} not lifted to global");
            // Confirm step: scores are exact inner products, bit-for-bit.
            let exact = crate::linalg::dot(data.row(gid), &q);
            assert_eq!(score.to_bits(), exact.to_bits());
        }
    }

    #[test]
    fn quant_tier_small_epsilon_falls_back_to_exact() {
        // ε → 0 leaves no budget for quantization bias: the two-tier
        // index must silently drop to the f32 tier and stay exact.
        let data = gaussian(80, 64, 21);
        let q: Vec<f32> = Rng::new(22).gaussian_vec(64);
        let truth = ground_truth(&data, &q, 3);
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let idx = BoundedMeIndex::new(data.clone()).with_storage(storage);
            let res = idx.query(
                &q,
                &MipsParams { k: 3, epsilon: 1e-9, delta: 0.05, seed: 7 },
            );
            let mut got = res.indices.clone();
            got.sort_unstable();
            let mut want = truth.clone();
            want.sort_unstable();
            assert_eq!(got, want, "{storage:?}");
        }
    }

    #[test]
    fn quant_tier_confirm_scores_are_exact_and_ranked() {
        let data = gaussian(100, 256, 23);
        let idx = BoundedMeIndex::with_order(data.clone(), PullOrder::BlockShuffled(32))
            .with_storage(Storage::F16);
        if idx.storage() == Storage::F32 {
            return; // RUST_PALLAS_FORCE_F32 leg: no compressed tier to test
        }
        let q: Vec<f32> = Rng::new(24).gaussian_vec(256);
        let res = idx.query(&q, &MipsParams { k: 4, epsilon: 0.2, delta: 0.1, seed: 3 });
        assert_eq!(res.indices.len(), 4);
        for (w, (&id, &score)) in res.indices.iter().zip(&res.scores).enumerate() {
            // Confirm step: returned scores are exact f32 inner
            // products, bit-for-bit, and ranked descending.
            let exact = crate::linalg::dot(data.row(id), &q);
            assert_eq!(score.to_bits(), exact.to_bits(), "survivor {w}");
            if w > 0 {
                assert!(score <= res.scores[w - 1], "not ranked at {w}");
            }
        }
        // Confirm flops are accounted on top of the sampled pulls.
        assert!(res.flops >= (4 * 256) as u64);
    }

    #[test]
    fn quant_tier_reports_effective_storage() {
        let idx = BoundedMeIndex::new(gaussian(10, 16, 25));
        assert_eq!(idx.storage(), Storage::F32);
        let idx = idx.with_storage(Storage::Int8);
        assert_eq!(idx.storage(), Storage::Int8.effective());
        // F32 request is always a no-op.
        let idx = BoundedMeIndex::new(gaussian(10, 16, 25)).with_storage(Storage::F32);
        assert_eq!(idx.storage(), Storage::F32);
    }

    #[test]
    fn quant_tier_is_epsilon_optimal_on_true_means() {
        // One-shot sanity check (the integration battery in
        // tests/quant_tier.rs does the statistical version): every
        // returned arm's true score must be within ε·range of the k-th
        // best true score.
        let data = gaussian(120, 128, 26);
        let q: Vec<f32> = Rng::new(27).gaussian_vec(128);
        let k = 5;
        let exact: Vec<f32> =
            (0..data.rows()).map(|i| crate::linalg::dot(data.row(i), &q)).collect();
        let mut sorted = exact.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let kth = sorted[k - 1] as f64;
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let idx = BoundedMeIndex::new(data.clone()).with_storage(storage);
            let params = MipsParams { k, epsilon: 0.05, delta: 0.05, seed: 11 };
            let res = idx.query(&q, &params);
            assert_eq!(res.indices.len(), k, "{storage:?}");
            // ε is range-relative in *mean* units; scores are mean × N,
            // so the allowed gap in score units is ε · 2·bound · N.
            let slack = params.epsilon
                * 2.0
                * idx.reward_bound(&q) as f64
                * data.cols() as f64;
            for &id in &res.indices {
                let score = exact[id] as f64;
                assert!(
                    score >= kth - slack - 1e-3,
                    "{storage:?}: arm {id} score {score} below kth {kth} − slack {slack}"
                );
            }
        }
    }

    #[test]
    fn f32_tier_override_is_bit_identical_to_plain_index() {
        // `query_with_tier(.., Storage::F32)` on a compressed deployment
        // must take exactly the plain-f32 code path: bit-identical
        // indices, scores, and flops to an index built without a tier.
        let data = gaussian(90, 128, 31);
        let plain = BoundedMeIndex::with_order(data.clone(), PullOrder::BlockShuffled(16));
        let quant = BoundedMeIndex::with_order(data, PullOrder::BlockShuffled(16))
            .with_storage(Storage::F16);
        let mut ctx_a = QueryContext::new();
        let mut ctx_b = QueryContext::new();
        for seed in 0..4u64 {
            let q: Vec<f32> = Rng::new(300 + seed).gaussian_vec(128);
            let params = MipsParams { k: 3, epsilon: 0.1, delta: 0.1, seed };
            let a = plain.query_with(&q, &params, &mut ctx_a);
            let b = quant.query_with_tier(&q, &params, &mut ctx_b, Storage::F32);
            assert_eq!(a.indices, b.indices, "seed={seed}");
            assert_eq!(a.flops, b.flops, "seed={seed}");
            for (x, y) in a.scores.iter().zip(&b.scores) {
                assert_eq!(x.to_bits(), y.to_bits(), "seed={seed}");
            }
        }
        // And the index's own tier delegates identically to the trait
        // entry point.
        let q: Vec<f32> = Rng::new(400).gaussian_vec(128);
        let params = MipsParams { k: 3, epsilon: 0.1, delta: 0.1, seed: 9 };
        let via_trait = quant.query_with(&q, &params, &mut ctx_a);
        let via_tier = quant.query_with_tier(&q, &params, &mut ctx_b, quant.storage());
        assert_eq!(via_trait.indices, via_tier.indices);
        assert_eq!(via_trait.flops, via_tier.flops);
    }

    #[test]
    fn unarmed_budget_entry_points_are_bit_identical_to_plain() {
        // `AnytimeBudget::NONE` must be invisible: same code path, same
        // bits, no harvest record — across tiers.
        use crate::bandit::AnytimeBudget;
        let data = gaussian(90, 128, 41);
        for storage in [Storage::F32, Storage::F16, Storage::Int8] {
            let idx = BoundedMeIndex::with_order(data.clone(), PullOrder::BlockShuffled(16))
                .with_storage(storage);
            let tier = idx.storage();
            let mut ctx_a = QueryContext::new();
            let mut ctx_b = QueryContext::new();
            for seed in 0..3u64 {
                let q: Vec<f32> = Rng::new(500 + seed).gaussian_vec(128);
                let params = MipsParams { k: 3, epsilon: 0.1, delta: 0.1, seed };
                let plain = idx.query_with_tier(&q, &params, &mut ctx_a, tier);
                let (budgeted, harvest) = idx.query_with_tier_budget(
                    &q,
                    &params,
                    &mut ctx_b,
                    tier,
                    AnytimeBudget::NONE,
                );
                assert!(harvest.is_none(), "{storage:?} seed={seed}");
                assert_eq!(plain.indices, budgeted.indices, "{storage:?} seed={seed}");
                assert_eq!(plain.flops, budgeted.flops, "{storage:?} seed={seed}");
                for (a, b) in plain.scores.iter().zip(&budgeted.scores) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{storage:?} seed={seed}");
                }
            }
        }
    }

    #[test]
    fn tiny_flop_budget_harvests_with_relative_epsilon_hat() {
        use crate::bandit::{force_no_degrade_requested, AnytimeBudget};
        let data = gaussian(120, 400, 43);
        let idx = BoundedMeIndex::with_order(data, PullOrder::BlockShuffled(16));
        let q: Vec<f32> = Rng::new(44).gaussian_vec(400);
        let params = MipsParams { k: 4, epsilon: 0.05, delta: 0.1, seed: 2 };
        let mut ctx = QueryContext::new();
        let budget =
            AnytimeBudget { deadline: None, budget_flops: Some(1) };
        let (res, harvest) = idx.query_with_tier_budget(&q, &params, &mut ctx, Storage::F32, budget);
        if force_no_degrade_requested() {
            // Degrade-leg CI: the kill switch must make the armed run
            // bit-identical to plain.
            assert!(harvest.is_none());
            let plain = idx.query(&q, &params);
            assert_eq!(res.indices, plain.indices);
            return;
        }
        let h = harvest.expect("1-flop budget must harvest");
        assert_eq!(h.rounds, 1);
        // Round-1 checkpoint: ε̂ = ε/2 in the same request-relative
        // units the caller supplied.
        assert!(
            (h.epsilon_hat - params.epsilon / 2.0).abs() < 1e-9,
            "epsilon_hat {} != eps/2 {}",
            h.epsilon_hat,
            params.epsilon / 2.0
        );
        assert_eq!(res.indices.len(), params.k);
        let full = idx.query(&q, &params);
        assert!(res.flops < full.flops, "harvest must cost less than a full run");
    }

    #[test]
    fn shard_budget_entry_point_confirms_harvested_survivors() {
        use crate::bandit::{force_no_degrade_requested, AnytimeBudget};
        use crate::data::shard::{ShardSpec, ShardedMatrix};
        let data = gaussian(80, 200, 45);
        let sm = ShardedMatrix::new(data.clone(), ShardSpec::contiguous(2));
        let shard = sm.shard(1); // rows 40..80
        let idx =
            BoundedMeIndex::with_order(shard.matrix().clone(), PullOrder::BlockShuffled(16));
        let q: Vec<f32> = Rng::new(46).gaussian_vec(200);
        let mut ctx = QueryContext::new();
        let params = MipsParams { k: 3, epsilon: 0.05, delta: 0.1, seed: 5 };
        let budget =
            AnytimeBudget { deadline: None, budget_flops: Some(1) };
        let (partial, harvest) =
            idx.query_shard_tier_budget(&q, &params, &mut ctx, shard, Storage::F32, budget);
        if !force_no_degrade_requested() {
            assert!(harvest.is_some(), "1-flop budget must harvest");
        }
        // Harvested or not, the confirm step still rescores exactly
        // under global ids.
        assert_eq!(partial.entries.len(), 3);
        for &(score, gid) in &partial.entries {
            assert!((40..80).contains(&gid), "id {gid} not lifted to global");
            let exact = crate::linalg::dot(data.row(gid), &q);
            assert_eq!(score.to_bits(), exact.to_bits());
        }
    }

    #[test]
    fn batch_shares_one_permutation() {
        let data = gaussian(50, 512, 11);
        let idx = BoundedMeIndex::with_order(data, PullOrder::BlockShuffled(64));
        let qs: Vec<Vec<f32>> = (0..16).map(|i| Rng::new(i).gaussian_vec(512)).collect();
        let refs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let params = MipsParams { k: 2, epsilon: 0.2, delta: 0.2, seed: 3 };
        let mut ctx = QueryContext::new();
        // Warm the context, then run the batch: no further buffer growth.
        let _ = idx.query_with(&qs[0], &params, &mut ctx);
        let warm = ctx.grow_events();
        let _ = idx.query_batch(&refs, &params, &mut ctx);
        assert_eq!(ctx.grow_events(), warm, "batch path reallocated scratch");
    }
}
