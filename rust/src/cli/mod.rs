//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, bare boolean `--flag`, and
//! positional arguments. Typed access with defaults via [`Args::get`].

use std::collections::HashMap;
use std::str::FromStr;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    /// `bool_flags` lists flags that never take a value, resolving the
    /// `--flag positional` ambiguity.
    pub fn parse_from_with<I: IntoIterator<Item = String>>(
        args: I,
        bool_flags: &[&str],
    ) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    flags.insert(name.to_string(), "true".to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    flags.insert(name.to_string(), v);
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self { flags, positional }
    }

    /// Parse from an iterator with no declared boolean flags.
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Self {
        Self::parse_from_with(args, &[])
    }

    /// Parse the process's arguments (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_with(&[])
    }

    /// Parse the process's arguments with declared boolean flags.
    pub fn parse_with(bool_flags: &[&str]) -> Self {
        Self::parse_from_with(std::env::args().skip(1), bool_flags)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Boolean flag present (either bare or `=true`).
    pub fn has(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v != "false").unwrap_or(false)
    }

    /// Typed flag with default.
    pub fn get<T: FromStr>(&self, name: &str, default: T) -> T {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Required typed flag.
    pub fn require<T: FromStr>(&self, name: &str) -> crate::Result<T> {
        self.flags
            .get(name)
            .ok_or_else(|| crate::errors::anyhow!("missing required --{name}"))?
            .parse()
            .map_err(|_| crate::errors::anyhow!("bad value for --{name}"))
    }

    /// Raw string flag.
    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }
}

/// Install stderr logging at the `RUST_LOG` level
/// (error|warn|info|debug|trace, default `info`) — see
/// [`crate::logkit`].
pub fn init_logger() {
    crate::logkit::init_from_env();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from_with(s.iter().map(|s| s.to_string()), &["full"])
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["gen", "--n", "100", "--dim=64", "--full", "out.bin"]);
        assert_eq!(a.command(), Some("gen"));
        assert_eq!(a.get("n", 0usize), 100);
        assert_eq!(a.get("dim", 0usize), 64);
        assert!(a.has("full"));
        assert!(!a.has("missing"));
        assert_eq!(a.positional(), &["gen".to_string(), "out.bin".to_string()]);
    }

    #[test]
    fn defaults_and_require() {
        let a = parse(&["--k", "5"]);
        assert_eq!(a.get("k", 1usize), 5);
        assert_eq!(a.get("eps", 0.25f64), 0.25);
        assert!(a.require::<usize>("k").is_ok());
        assert!(a.require::<usize>("nope").is_err());
    }

    #[test]
    fn bool_flag_followed_by_flag() {
        let a = parse(&["--full", "--n", "3"]);
        assert!(a.has("full"));
        assert_eq!(a.get("n", 0usize), 3);
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["--offset", "-3"]);
        assert_eq!(a.get("offset", 0i64), -3);
    }
}
