//! Dataset sharding: row-range (or round-robin) shards over one backing
//! [`Matrix`].
//!
//! BOUNDEDME shards naturally: arm pulls and exact rescoring are both
//! row-local, so a dataset larger than one worker's cache-friendly slice
//! can be split by rows, queried per shard, and merged by top-K — the
//! adaptive-sampling decomposition of BanditMIPS (Tiwari et al., 2022)
//! applied to the serving layer. This module is the *data* half of that
//! story: [`ShardSpec`] describes how rows are assigned to shards and
//! [`ShardedMatrix`] materializes the assignment. The *execution* half —
//! per-shard (ε, δ) accounting, fan-out, and the top-K merge — lives in
//! [`crate::exec::shard`].
//!
//! Contiguous shards are zero-copy [`Matrix::view_rows`] views sharing
//! the backing storage (a shard reads the very same bytes as the
//! unsharded matrix, which is what makes sharded exact scoring
//! byte-identical). Round-robin shards interleave rows across shards —
//! useful when row norms drift with row index (e.g. popularity-sorted
//! item catalogs) and a contiguous split would concentrate all the hot
//! arms on one shard; they are materialized by gathering (one copy at
//! build time, row-local afterwards).

use crate::linalg::Matrix;

/// How dataset rows are assigned to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardSpec {
    /// Shard `s` owns a contiguous row range; ranges are balanced so the
    /// first `rows % shards` shards hold one extra row (ragged splits
    /// where `rows % shards != 0` are first-class). Zero-copy.
    Contiguous {
        /// Number of shards (clamped to `[1, rows]` at build time).
        shards: usize,
    },
    /// Shard `s` owns rows `{s, s + S, s + 2S, …}`. Copying (gathered at
    /// build time), but immune to row-order skew.
    RoundRobin {
        /// Number of shards (clamped to `[1, rows]` at build time).
        shards: usize,
    },
}

impl ShardSpec {
    /// Contiguous split into `shards` shards.
    pub fn contiguous(shards: usize) -> Self {
        Self::Contiguous { shards }
    }

    /// Round-robin split into `shards` shards.
    pub fn round_robin(shards: usize) -> Self {
        Self::RoundRobin { shards }
    }

    /// The trivial one-shard spec (sharding disabled).
    pub fn single() -> Self {
        Self::Contiguous { shards: 1 }
    }

    /// Requested shard count (before clamping against the row count).
    pub fn shards(&self) -> usize {
        match *self {
            Self::Contiguous { shards } | Self::RoundRobin { shards } => shards,
        }
    }

    /// Short label for benches/metrics ("contig" / "rr").
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Contiguous { .. } => "contig",
            Self::RoundRobin { .. } => "rr",
        }
    }
}

/// Row-id mapping of one shard: local row → dataset-global row.
#[derive(Clone)]
enum ShardIds {
    /// Contiguous: `global = offset + local`.
    Offset(usize),
    /// Round-robin: `global = list[local]`.
    List(Vec<usize>),
}

/// One shard: a dense matrix of its rows plus the local→global row map.
/// Cloning is cheap for contiguous shards (the matrix is an `Arc`-backed
/// view) — [`crate::data::generation`] relies on that to carry untouched
/// shards across generations without copying a byte.
#[derive(Clone)]
pub struct Shard {
    matrix: Matrix,
    ids: ShardIds,
}

impl Shard {
    /// Crate-internal: a contiguous shard whose local row `i` is global
    /// row `offset + i`. Used by the generation builder, which assembles
    /// shard sets directly instead of slicing one backing matrix.
    pub(crate) fn from_offset(matrix: Matrix, offset: usize) -> Self {
        Self { matrix, ids: ShardIds::Offset(offset) }
    }

    /// Crate-internal: a gathered shard with an explicit local→global id
    /// list (`ids.len()` must equal `matrix.rows()`).
    pub(crate) fn from_ids(matrix: Matrix, ids: Vec<usize>) -> Self {
        debug_assert_eq!(ids.len(), matrix.rows(), "shard id list / row mismatch");
        Self { matrix, ids: ShardIds::List(ids) }
    }

    /// The shard's rows as a dense matrix (a zero-copy view for
    /// contiguous shards).
    #[inline]
    pub fn matrix(&self) -> &Matrix {
        &self.matrix
    }

    /// Rows in this shard.
    #[inline]
    pub fn rows(&self) -> usize {
        self.matrix.rows()
    }

    /// Dataset-global id of local row `local`.
    #[inline]
    pub fn global_id(&self, local: usize) -> usize {
        debug_assert!(local < self.rows(), "shard row {local} out of range");
        match &self.ids {
            ShardIds::Offset(off) => off + local,
            ShardIds::List(ids) => ids[local],
        }
    }
}

/// A dataset split into row shards per a [`ShardSpec`].
///
/// The shard count is clamped to `[1, rows]` (an empty shard has no
/// arms to pull and no rows to scan — it would only complicate the
/// (ε, δ) accounting), so `num_shards()` may be smaller than requested
/// on tiny datasets.
pub struct ShardedMatrix {
    backing: Matrix,
    spec: ShardSpec,
    shards: Vec<Shard>,
}

impl ShardedMatrix {
    /// Split `backing` per `spec`.
    pub fn new(backing: Matrix, spec: ShardSpec) -> Self {
        let rows = backing.rows();
        let s = spec.shards().clamp(1, rows.max(1));
        let shards = match spec {
            ShardSpec::Contiguous { .. } => {
                let base = rows / s;
                let extra = rows % s;
                let mut out = Vec::with_capacity(s);
                let mut first = 0usize;
                for j in 0..s {
                    let len = base + usize::from(j < extra);
                    out.push(Shard {
                        matrix: backing.view_rows(first, len),
                        ids: ShardIds::Offset(first),
                    });
                    first += len;
                }
                out
            }
            ShardSpec::RoundRobin { .. } => (0..s)
                .map(|j| {
                    let ids: Vec<usize> = (j..rows).step_by(s).collect();
                    Shard {
                        matrix: backing.gather_rows(&ids),
                        ids: ShardIds::List(ids),
                    }
                })
                .collect(),
        };
        Self { backing, spec, shards }
    }

    /// The unsharded backing matrix.
    pub fn backing(&self) -> &Matrix {
        &self.backing
    }

    /// The spec this split was built from (as requested, pre-clamp).
    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Effective shard count after clamping.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// All shards.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Shard `s`.
    pub fn shard(&self, s: usize) -> &Shard {
        &self.shards[s]
    }

    /// Total rows (equals the backing matrix's).
    pub fn rows(&self) -> usize {
        self.backing.rows()
    }

    /// Vector dimension `N` (shared by every shard — sharding splits
    /// rows, never coordinates, so pull orders and [`crate::exec::QueryPlan`]
    /// decisions are shard-count invariant by construction).
    pub fn dim(&self) -> usize {
        self.backing.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numbered(rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| (r * cols + c) as f32)
    }

    /// Every row appears in exactly one shard, with the right contents.
    fn assert_partition(sm: &ShardedMatrix) {
        let rows = sm.rows();
        let mut seen = vec![false; rows];
        for shard in sm.shards() {
            for local in 0..shard.rows() {
                let g = shard.global_id(local);
                assert!(!seen[g], "row {g} in two shards");
                seen[g] = true;
                assert_eq!(shard.matrix().row(local), sm.backing().row(g));
            }
        }
        assert!(seen.into_iter().all(|s| s), "rows missing from shards");
    }

    #[test]
    fn contiguous_even_and_ragged() {
        for (rows, s) in [(12, 3), (13, 3), (10, 7), (5, 5)] {
            let sm = ShardedMatrix::new(numbered(rows, 4), ShardSpec::contiguous(s));
            assert_eq!(sm.num_shards(), s);
            // Balanced: sizes differ by at most one, larger shards first.
            let sizes: Vec<usize> = sm.shards().iter().map(Shard::rows).collect();
            assert_eq!(sizes.iter().sum::<usize>(), rows);
            assert!(sizes.windows(2).all(|w| w[0] >= w[1] && w[0] - w[1] <= 1));
            assert_partition(&sm);
        }
    }

    #[test]
    fn contiguous_shards_are_views() {
        let m = numbered(9, 3);
        let sm = ShardedMatrix::new(m.clone(), ShardSpec::contiguous(2));
        for shard in sm.shards() {
            assert!(shard.matrix().shares_storage(&m), "contiguous shard copied");
        }
        // Shard 0 gets the extra row on ragged splits.
        assert_eq!(sm.shard(0).rows(), 5);
        assert_eq!(sm.shard(1).rows(), 4);
        assert_eq!(sm.shard(1).global_id(0), 5);
    }

    #[test]
    fn round_robin_interleaves() {
        let sm = ShardedMatrix::new(numbered(10, 2), ShardSpec::round_robin(3));
        assert_eq!(sm.num_shards(), 3);
        assert_eq!(sm.shard(0).rows(), 4); // rows 0, 3, 6, 9
        assert_eq!(sm.shard(1).rows(), 3); // rows 1, 4, 7
        assert_eq!(sm.shard(0).global_id(3), 9);
        assert_eq!(sm.shard(2).global_id(1), 5);
        assert_partition(&sm);
    }

    #[test]
    fn shard_count_clamps_to_rows() {
        let sm = ShardedMatrix::new(numbered(3, 2), ShardSpec::contiguous(8));
        assert_eq!(sm.num_shards(), 3);
        for shard in sm.shards() {
            assert_eq!(shard.rows(), 1); // single-row shards
        }
        let sm = ShardedMatrix::new(numbered(3, 2), ShardSpec::round_robin(0));
        assert_eq!(sm.num_shards(), 1);
        assert_partition(&sm);
    }

    #[test]
    fn single_spec_is_identity() {
        let m = numbered(6, 2);
        let sm = ShardedMatrix::new(m.clone(), ShardSpec::single());
        assert_eq!(sm.num_shards(), 1);
        assert_eq!(*sm.shard(0).matrix(), m);
        assert_eq!(sm.shard(0).global_id(4), 4);
        assert_eq!(sm.spec().kind(), "contig");
        assert_eq!(ShardSpec::round_robin(2).kind(), "rr");
    }
}
