//! Generation-swapped live mutation: streaming upserts / deletes /
//! appends under traffic, with zero rebuild of anything the paper's
//! algorithm needs.
//!
//! # Why no-preprocessing makes rebuild-free mutation sound
//!
//! Index-based MIPS (LSH tables, quantization codebooks, proximity
//! graphs) bakes the dataset into a derived structure, so a row churn
//! invalidates preprocessing that can cost minutes to redo — streaming
//! catalogs force a painful rebuild-vs-staleness tradeoff. BOUNDEDME
//! has **no preprocessing**: a query needs only the raw rows (plus
//! per-shard column maxima and, for compressed tiers, the quantized
//! codes — both one linear pass over exactly the rows that changed).
//! Swapping in a new set of rows therefore yields *immediately correct*
//! answers with the full (ε, δ) guarantee; there is no staleness window
//! and nothing to patch incrementally. Mutation reduces to a data
//! versioning problem, which this module solves with immutable
//! **generations**.
//!
//! # The flip / pin / reclaim lifecycle
//!
//! * **Build**: a writer turns generation `N` into generation `N+1`
//!   through a [`GenerationBuilder`] (upserts = in-place row
//!   replacement, deletes = tombstoned-then-compacted rows, appends =
//!   new rows at the tail). Generations are immutable; the builder
//!   assembles the new shard set **copy-on-write**: a shard whose rows
//!   are untouched is carried over as an `Arc` clone of the parent's
//!   zero-copy [`Matrix::view_rows`] view — same bytes, no copy, and
//!   (one layer up, in [`crate::exec::shard::ShardSet`]) the same
//!   column maxima and quantized codes. Only shards that deltas
//!   actually hit are re-materialized, and their delta rows get fresh
//!   per-row quantization error bounds when re-indexed.
//! * **Flip**: the serving side (the coordinator's reactor, or the
//!   `S = 1` direct workers) swaps its local `Arc` to the new
//!   generation **between batches** — a pointer move, no lock, and
//!   never mid-batch, so one batch never sees two generations.
//! * **Pin**: every admitted query captures the `Arc` of the
//!   generation it was admitted under and finishes on it, even if the
//!   world has flipped several times since. Answers are therefore
//!   always exact for *one specific* snapshot that overlapped the
//!   query's lifetime — the linearizability contract the
//!   `generation_equivalence` battery asserts.
//! * **Reclaim**: when the last pinned query context drops its `Arc`,
//!   the generation (and any shard buffers no newer generation still
//!   references) is freed. Reclamation is epoch-observed through
//!   [`crate::sync::EpochGauge`]: each generation holds an
//!   [`crate::sync::EpochGuard`], so "generations alive" is a relaxed
//!   atomic read — the churn bench reports it and the stress leg
//!   asserts it returns to 1 after quiesce.
//!
//! # Row ids and shard layout
//!
//! Row ids are dense per generation (`0..rows`): a delete compacts the
//! ids above it, an append takes the next id. Query responses carry
//! the generation id, so a client maps returned row ids against the
//! catalog version it was answered from. The shard *count* is fixed
//! for the lifetime of a serving deployment (worker topology is pinned
//! at spawn); within that count, pure upserts keep every shard's row
//! range stable — the common steady-state churn (embedding refresh,
//! price updates) flips with O(dirty shards) work — while
//! size-changing deltas (deletes/appends) rebalance and re-materialize
//! every shard, exactly like a fresh [`ShardedMatrix`] build.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::data::shard::{Shard, ShardSpec, ShardedMatrix};
use crate::linalg::Matrix;
use crate::sync::{EpochGauge, EpochGuard};

/// One mutation: the unit a `mutate` request is made of. `id`s refer to
/// the row numbering of the generation the batch is applied to.
#[derive(Clone, Debug, PartialEq)]
pub enum Delta {
    /// Replace row `id` with `vector`.
    Upsert {
        /// Row to replace (must exist in the base generation).
        id: usize,
        /// Replacement row (base dimension).
        vector: Vec<f32>,
    },
    /// Remove row `id`; higher ids compact down by one.
    Delete {
        /// Row to remove (must exist in the base generation).
        id: usize,
    },
    /// Add a row at the tail (new id = old `rows`, then +1 per append).
    Append {
        /// New row (base dimension).
        vector: Vec<f32>,
    },
}

/// Why a delta batch could not be applied.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GenerationError {
    /// Upsert/delete of a row the base generation does not have.
    BadRow {
        /// Offending row id.
        id: usize,
        /// Base generation row count.
        rows: usize,
    },
    /// Upsert/append vector of the wrong dimension.
    DimMismatch {
        /// Dimension of the offending vector.
        got: usize,
        /// The dataset dimension.
        want: usize,
    },
    /// The same row both upserted and deleted in one batch.
    Conflict {
        /// Offending row id.
        id: usize,
    },
    /// The batch would shrink the dataset below one row per shard (the
    /// serving topology pins the shard count at spawn, and an empty
    /// shard has no arms to pull).
    TooFewRows {
        /// Row count the batch would leave.
        rows: usize,
        /// Fixed shard count.
        shards: usize,
    },
}

impl std::fmt::Display for GenerationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::BadRow { id, rows } => write!(f, "row {id} out of range (rows {rows})"),
            Self::DimMismatch { got, want } => {
                write!(f, "vector dimension {got} != dataset dimension {want}")
            }
            Self::Conflict { id } => {
                write!(f, "row {id} both upserted and deleted in one batch")
            }
            Self::TooFewRows { rows, shards } => {
                write!(f, "batch leaves {rows} rows < {shards} shards")
            }
        }
    }
}

impl std::error::Error for GenerationError {}

/// One immutable dataset version: a shard set plus a monotonically
/// increasing id. See the module docs for the flip/pin/reclaim
/// lifecycle.
pub struct Generation {
    id: u64,
    spec: ShardSpec,
    shards: Vec<Shard>,
    /// Contiguous layout only: first global id per shard (for O(log S)
    /// row lookup). Empty for round-robin.
    starts: Vec<usize>,
    rows: usize,
    dim: usize,
    gauge: EpochGauge,
    _guard: EpochGuard,
}

impl Generation {
    /// Generation 0: shard `data` per `spec` (identical layout to
    /// [`ShardedMatrix::new`] — contiguous shards are zero-copy views)
    /// and register it on `gauge`.
    pub fn initial(data: Matrix, spec: ShardSpec, gauge: EpochGauge) -> Arc<Generation> {
        let sharded = ShardedMatrix::new(data, spec);
        let shards: Vec<Shard> = sharded.shards().to_vec();
        Arc::new(Self::assemble(0, spec, shards, sharded.rows(), sharded.dim(), gauge))
    }

    fn assemble(
        id: u64,
        spec: ShardSpec,
        shards: Vec<Shard>,
        rows: usize,
        dim: usize,
        gauge: EpochGauge,
    ) -> Generation {
        let starts = match spec {
            ShardSpec::Contiguous { .. } => shards
                .iter()
                .map(|s| if s.rows() == 0 { 0 } else { s.global_id(0) })
                .collect(),
            ShardSpec::RoundRobin { .. } => Vec::new(),
        };
        let guard = gauge.register();
        Generation { id, spec, shards, starts, rows, dim, gauge, _guard: guard }
    }

    /// Monotonic generation id (0 for the initial build).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Logical row count of this generation.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Vector dimension (invariant across generations).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Shard count (fixed across generations of one lineage).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `s`.
    pub fn shard(&self, s: usize) -> &Shard {
        &self.shards[s]
    }

    /// All shards.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// The spec the lineage was built with.
    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Which shard owns global row `g`.
    fn shard_of(&self, g: usize) -> usize {
        debug_assert!(g < self.rows);
        match self.spec {
            ShardSpec::Contiguous { .. } => self.starts.partition_point(|&s| s <= g) - 1,
            ShardSpec::RoundRobin { .. } => g % self.shards.len(),
        }
    }

    /// Global row `g` as a slice (shard-indirected).
    pub fn row(&self, g: usize) -> &[f32] {
        let s = self.shard_of(g);
        let local = match self.spec {
            ShardSpec::Contiguous { .. } => g - self.starts[s],
            ShardSpec::RoundRobin { .. } => g / self.shards.len(),
        };
        self.shards[s].matrix().row(local)
    }

    /// The full snapshot as one dense matrix in global row order (a
    /// copy). This is the *reference* view of the generation: the
    /// equivalence batteries build from-scratch indexes on it and
    /// demand bit-identical answers from the generation-pinned path.
    pub fn materialize(&self) -> Matrix {
        let mut buf = vec![0f32; self.rows * self.dim];
        for shard in &self.shards {
            for local in 0..shard.rows() {
                let g = shard.global_id(local);
                buf[g * self.dim..(g + 1) * self.dim]
                    .copy_from_slice(shard.matrix().row(local));
            }
        }
        Matrix::from_vec(self.rows, self.dim, buf)
    }
}

/// Result of one [`GenerationBuilder::build`]: the new generation plus
/// the copy-on-write bookkeeping the index layer needs to carry
/// untouched per-shard state (column maxima, quantized codes) across
/// the flip.
pub struct GenerationBuild {
    /// The new generation.
    pub generation: Arc<Generation>,
    /// Per new shard: `Some(j)` when it is byte-for-byte the base's
    /// shard `j` (same rows, same order, shared storage) — the index
    /// layer may reuse shard `j`'s derived state verbatim. `None` for
    /// re-materialized shards, whose delta rows must be re-quantized
    /// with fresh error bounds.
    pub reuse: Vec<Option<usize>>,
    /// Rows copied into re-materialized shards (0 for a no-op batch).
    pub rows_copied: usize,
    /// Deltas applied (upserts + deletes + appends).
    pub delta_rows: usize,
}

/// Writer-side accumulator building generation `N+1` from `N`. All row
/// ids refer to the **base** generation; the whole batch applies
/// atomically at [`GenerationBuilder::build`].
pub struct GenerationBuilder<'a> {
    base: &'a Generation,
    upserts: BTreeMap<usize, Vec<f32>>,
    deletes: BTreeSet<usize>,
    appends: Vec<Vec<f32>>,
}

impl<'a> GenerationBuilder<'a> {
    /// Start a delta batch over `base`.
    pub fn new(base: &'a Generation) -> Self {
        Self { base, upserts: BTreeMap::new(), deletes: BTreeSet::new(), appends: Vec::new() }
    }

    fn check_dim(&self, v: &[f32]) -> Result<(), GenerationError> {
        if v.len() != self.base.dim() {
            return Err(GenerationError::DimMismatch { got: v.len(), want: self.base.dim() });
        }
        Ok(())
    }

    fn check_row(&self, id: usize) -> Result<(), GenerationError> {
        if id >= self.base.rows() {
            return Err(GenerationError::BadRow { id, rows: self.base.rows() });
        }
        Ok(())
    }

    /// Replace base row `id` (last upsert of an id wins).
    pub fn upsert(&mut self, id: usize, vector: Vec<f32>) -> Result<(), GenerationError> {
        self.check_row(id)?;
        self.check_dim(&vector)?;
        if self.deletes.contains(&id) {
            return Err(GenerationError::Conflict { id });
        }
        self.upserts.insert(id, vector);
        Ok(())
    }

    /// Remove base row `id` (idempotent within a batch).
    pub fn delete(&mut self, id: usize) -> Result<(), GenerationError> {
        self.check_row(id)?;
        if self.upserts.contains_key(&id) {
            return Err(GenerationError::Conflict { id });
        }
        self.deletes.insert(id);
        Ok(())
    }

    /// Add a row at the tail.
    pub fn append(&mut self, vector: Vec<f32>) -> Result<(), GenerationError> {
        self.check_dim(&vector)?;
        self.appends.push(vector);
        Ok(())
    }

    /// Apply one [`Delta`] (clones the vector).
    pub fn apply(&mut self, delta: &Delta) -> Result<(), GenerationError> {
        match delta {
            Delta::Upsert { id, vector } => self.upsert(*id, vector.clone()),
            Delta::Delete { id } => self.delete(*id),
            Delta::Append { vector } => self.append(vector.clone()),
        }
    }

    /// Deltas accumulated so far.
    pub fn delta_rows(&self) -> usize {
        self.upserts.len() + self.deletes.len() + self.appends.len()
    }

    /// True when the batch is a no-op.
    pub fn is_empty(&self) -> bool {
        self.delta_rows() == 0
    }

    /// Materialize generation `base.id() + 1`. Copy-on-write: with a
    /// pure-upsert batch, only shards an upsert lands in are rebuilt;
    /// size-changing batches rebalance (and therefore rebuild) every
    /// shard. An empty batch produces an identical generation with a
    /// bumped id (all shards reused).
    pub fn build(self) -> Result<GenerationBuild, GenerationError> {
        let base = self.base;
        let (n, d) = (base.rows(), base.dim());
        let s_count = base.num_shards();
        let delta_rows = self.delta_rows();

        // New global row list: surviving base rows in order (upserts
        // applied in place), then appends at the tail.
        enum Src<'b> {
            Keep(usize),
            Fresh(&'b [f32]),
        }
        let mut sources: Vec<Src> = Vec::with_capacity(n - self.deletes.len() + self.appends.len());
        for old in 0..n {
            if self.deletes.contains(&old) {
                continue;
            }
            sources.push(match self.upserts.get(&old) {
                Some(v) => Src::Fresh(v),
                None => Src::Keep(old),
            });
        }
        for v in &self.appends {
            sources.push(Src::Fresh(v));
        }
        let n2 = sources.len();
        if n2 < s_count {
            return Err(GenerationError::TooFewRows { rows: n2, shards: s_count });
        }

        // A shard is carried over untouched only when the batch cannot
        // have moved any row in or out of it: no size change, and no
        // upsert landing inside it.
        let pure_upserts = self.deletes.is_empty() && self.appends.is_empty();
        let mut dirty = vec![!pure_upserts; s_count];
        if pure_upserts {
            for &id in self.upserts.keys() {
                dirty[base.shard_of(id)] = true;
            }
        }

        let mut shards = Vec::with_capacity(s_count);
        let mut reuse = vec![None; s_count];
        let mut rows_copied = 0usize;
        let fill = |ids: &[usize], buf: &mut Vec<f32>| {
            for &g in ids {
                match &sources[g] {
                    Src::Keep(old) => buf.extend_from_slice(base.row(*old)),
                    Src::Fresh(v) => buf.extend_from_slice(v),
                }
            }
        };
        match base.spec() {
            ShardSpec::Contiguous { .. } => {
                let (per, extra) = (n2 / s_count, n2 % s_count);
                let mut first = 0usize;
                for j in 0..s_count {
                    let len = per + usize::from(j < extra);
                    if !dirty[j] {
                        // Pure upserts keep n2 == n, so the balanced
                        // range of shard j is exactly the base's.
                        debug_assert_eq!(first, base.shard(j).global_id(0));
                        debug_assert_eq!(len, base.shard(j).rows());
                        shards.push(base.shard(j).clone());
                        reuse[j] = Some(j);
                    } else {
                        let ids: Vec<usize> = (first..first + len).collect();
                        let mut buf = Vec::with_capacity(len * d);
                        fill(&ids, &mut buf);
                        rows_copied += len;
                        shards.push(Shard::from_offset(Matrix::from_vec(len, d, buf), first));
                    }
                    first += len;
                }
            }
            ShardSpec::RoundRobin { .. } => {
                for j in 0..s_count {
                    let ids: Vec<usize> = (j..n2).step_by(s_count).collect();
                    if !dirty[j] {
                        shards.push(base.shard(j).clone());
                        reuse[j] = Some(j);
                    } else {
                        let mut buf = Vec::with_capacity(ids.len() * d);
                        fill(&ids, &mut buf);
                        rows_copied += ids.len();
                        shards.push(Shard::from_ids(Matrix::from_vec(ids.len(), d, buf), ids));
                    }
                }
            }
        }

        let generation = Arc::new(Generation::assemble(
            base.id() + 1,
            base.spec(),
            shards,
            n2,
            d,
            base.gauge.clone(),
        ));
        Ok(GenerationBuild { generation, reuse, rows_copied, delta_rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn numbered(rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| (r * cols + c) as f32)
    }

    fn gen0(rows: usize, cols: usize, spec: ShardSpec) -> Arc<Generation> {
        Generation::initial(numbered(rows, cols), spec, EpochGauge::new())
    }

    /// Shadow model: apply the same batch semantics to a plain Vec.
    fn shadow(
        base: &Matrix,
        upserts: &[(usize, Vec<f32>)],
        deletes: &[usize],
        appends: &[Vec<f32>],
    ) -> Vec<Vec<f32>> {
        let mut rows: Vec<Option<Vec<f32>>> =
            (0..base.rows()).map(|r| Some(base.row(r).to_vec())).collect();
        for &(id, ref v) in upserts {
            rows[id] = Some(v.clone());
        }
        for &id in deletes {
            rows[id] = None;
        }
        let mut out: Vec<Vec<f32>> = rows.into_iter().flatten().collect();
        out.extend(appends.iter().cloned());
        out
    }

    fn assert_matches_shadow(g: &Generation, want: &[Vec<f32>]) {
        assert_eq!(g.rows(), want.len());
        let m = g.materialize();
        for (r, w) in want.iter().enumerate() {
            assert_eq!(m.row(r), &w[..], "row {r}");
            assert_eq!(g.row(r), &w[..], "row() lookup {r}");
        }
        // Every row appears in exactly one shard with the right bytes.
        let mut seen = vec![false; g.rows()];
        for shard in g.shards() {
            for local in 0..shard.rows() {
                let gid = shard.global_id(local);
                assert!(!seen[gid], "row {gid} in two shards");
                seen[gid] = true;
                assert_eq!(shard.matrix().row(local), &want[gid][..]);
            }
        }
        assert!(seen.into_iter().all(|s| s));
    }

    #[test]
    fn pure_upserts_rebuild_only_dirty_shards() {
        let base = gen0(12, 4, ShardSpec::contiguous(3)); // shards of 4 rows
        let mut b = GenerationBuilder::new(&base);
        let v = vec![9.0; 4];
        b.upsert(5, v.clone()).unwrap(); // lands in shard 1
        let built = b.build().unwrap();
        assert_eq!(built.reuse, vec![Some(0), None, Some(2)]);
        assert_eq!(built.rows_copied, 4);
        assert_eq!(built.generation.id(), 1);
        // Untouched shards share storage with the base's views.
        assert!(built
            .generation
            .shard(0)
            .matrix()
            .shares_storage(base.shard(0).matrix()));
        assert!(!built
            .generation
            .shard(1)
            .matrix()
            .shares_storage(base.shard(1).matrix()));
        let want = shadow(&base.materialize(), &[(5, v)], &[], &[]);
        assert_matches_shadow(&built.generation, &want);
    }

    #[test]
    fn deletes_and_appends_rebalance_every_shard() {
        let m = numbered(10, 3);
        let base = Generation::initial(m.clone(), ShardSpec::contiguous(3), EpochGauge::new());
        let mut b = GenerationBuilder::new(&base);
        b.delete(0).unwrap();
        b.delete(7).unwrap();
        b.append(vec![-1.0, -2.0, -3.0]).unwrap();
        let built = b.build().unwrap();
        assert_eq!(built.reuse, vec![None, None, None]);
        assert_eq!(built.generation.rows(), 9);
        let want = shadow(&m, &[], &[0, 7], &[vec![-1.0, -2.0, -3.0]]);
        assert_matches_shadow(&built.generation, &want);
    }

    #[test]
    fn round_robin_upserts_reuse_untouched_interleaves() {
        let m = numbered(10, 2);
        let base = Generation::initial(m.clone(), ShardSpec::round_robin(3), EpochGauge::new());
        let mut b = GenerationBuilder::new(&base);
        let v = vec![7.0, 8.0];
        b.upsert(4, v.clone()).unwrap(); // 4 % 3 == 1 → shard 1 dirty
        let built = b.build().unwrap();
        assert_eq!(built.reuse, vec![Some(0), None, Some(2)]);
        let want = shadow(&m, &[(4, v)], &[], &[]);
        assert_matches_shadow(&built.generation, &want);
    }

    #[test]
    fn round_robin_size_change_reinterleaves() {
        let m = numbered(9, 2);
        let base = Generation::initial(m.clone(), ShardSpec::round_robin(2), EpochGauge::new());
        let mut b = GenerationBuilder::new(&base);
        b.append(vec![5.0, 5.0]).unwrap();
        b.delete(2).unwrap();
        let built = b.build().unwrap();
        let want = shadow(&m, &[], &[2], &[vec![5.0, 5.0]]);
        assert_matches_shadow(&built.generation, &want);
    }

    #[test]
    fn chained_generations_stay_consistent() {
        let mut rng = Rng::new(0xC4A1);
        let m = Matrix::from_fn(20, 6, |_, _| rng.gaussian() as f32);
        let gauge = EpochGauge::new();
        let mut current = Generation::initial(m.clone(), ShardSpec::contiguous(4), gauge.clone());
        let mut want: Vec<Vec<f32>> = (0..m.rows()).map(|r| m.row(r).to_vec()).collect();
        for step in 0..5u64 {
            let mut b = GenerationBuilder::new(&current);
            let id = (step as usize * 3) % want.len();
            let v: Vec<f32> = rng.gaussian_vec(6);
            b.upsert(id, v.clone()).unwrap();
            if step % 2 == 0 {
                b.append(rng.gaussian_vec(6)).unwrap();
            }
            let appends: Vec<Vec<f32>> =
                if step % 2 == 0 { vec![b.appends[0].clone()] } else { vec![] };
            let snap = Matrix::from_rows(&want);
            let built = b.build().unwrap();
            want = shadow(&snap, &[(id, v)], &[], &appends);
            assert_eq!(built.generation.id(), step + 1);
            assert_matches_shadow(&built.generation, &want);
            current = built.generation;
        }
    }

    #[test]
    fn empty_batch_bumps_id_and_reuses_everything() {
        let base = gen0(8, 2, ShardSpec::contiguous(2));
        let built = GenerationBuilder::new(&base).build().unwrap();
        assert_eq!(built.generation.id(), 1);
        assert_eq!(built.reuse, vec![Some(0), Some(1)]);
        assert_eq!(built.rows_copied, 0);
    }

    #[test]
    fn delta_validation_errors() {
        let base = gen0(6, 3, ShardSpec::contiguous(2));
        let mut b = GenerationBuilder::new(&base);
        assert_eq!(
            b.upsert(6, vec![0.0; 3]),
            Err(GenerationError::BadRow { id: 6, rows: 6 })
        );
        assert_eq!(
            b.upsert(0, vec![0.0; 4]),
            Err(GenerationError::DimMismatch { got: 4, want: 3 })
        );
        assert_eq!(b.append(vec![0.0; 2]), Err(GenerationError::DimMismatch { got: 2, want: 3 }));
        b.delete(1).unwrap();
        assert_eq!(b.upsert(1, vec![0.0; 3]), Err(GenerationError::Conflict { id: 1 }));
        b.upsert(2, vec![0.0; 3]).unwrap();
        assert_eq!(b.delete(2), Err(GenerationError::Conflict { id: 2 }));
        // Shrinking below the shard count is refused.
        let mut b = GenerationBuilder::new(&base);
        for id in 0..5 {
            b.delete(id).unwrap();
        }
        assert_eq!(
            b.build().map(|_| ()),
            Err(GenerationError::TooFewRows { rows: 1, shards: 2 })
        );
    }

    #[test]
    fn gauge_tracks_generation_lifetimes() {
        let gauge = EpochGauge::new();
        let base = Generation::initial(numbered(6, 2), ShardSpec::contiguous(2), gauge.clone());
        assert_eq!(gauge.alive(), 1);
        let built = GenerationBuilder::new(&base).build().unwrap();
        assert_eq!(gauge.alive(), 2);
        drop(base);
        assert_eq!(gauge.alive(), 1);
        drop(built);
        assert_eq!(gauge.alive(), 0);
        assert_eq!(gauge.created(), 2);
    }

    #[test]
    fn applies_delta_enum() {
        let base = gen0(6, 2, ShardSpec::contiguous(2));
        let mut b = GenerationBuilder::new(&base);
        b.apply(&Delta::Upsert { id: 0, vector: vec![1.0, 1.0] }).unwrap();
        b.apply(&Delta::Delete { id: 3 }).unwrap();
        b.apply(&Delta::Append { vector: vec![2.0, 2.0] }).unwrap();
        assert_eq!(b.delta_rows(), 3);
        assert!(!b.is_empty());
        let built = b.build().unwrap();
        assert_eq!(built.delta_rows, 3);
        assert_eq!(built.generation.rows(), 6);
    }
}
