//! Mixed-precision dataset tier: compressed storage with recorded
//! quantization error.
//!
//! Every hot path is memory-bandwidth-bound, so the [`Storage`] axis —
//! `f16`, `bf16`, or `int8` with a per-row scale — halves or quarters
//! the bytes streamed per coordinate pull. The catch is that the
//! bandit's (ε, δ) confidence argument assumes the rewards it samples
//! are the true rewards; a lossy tier breaks that unless the error is
//! *accounted for*. [`QuantMatrix::quantize`] therefore records, per
//! row, the max absolute dequantization error
//! (`max_j |deq(code_ij) − v_ij|`). The two-tier query path (see
//! [`crate::algos::BoundedMeIndex`]) turns that into a bound on the
//! mean-reward bias — for a query `q`, the lossy mean of arm `i` is
//! within `row_err(i)·‖q‖₁/N` of the true mean — shrinks its effective
//! ε by twice that bias, samples the bandit on the compressed tier, and
//! confirm-rescores the returned arms exactly on f32. The guarantee
//! survives because ε-optimality under the lossy means plus a uniform
//! mean bias `b` implies (ε + 2b)-optimality under the true means.
//!
//! The compressed codes live in [`Arc`]s so a `QuantMatrix` clones
//! cheaply alongside its parent [`Matrix`] (same pattern as the
//! zero-copy shard views). Scoring kernels over the codes live in
//! [`crate::linalg::simd::wide`]; this module is storage + error
//! accounting only.
//!
//! `RUST_PALLAS_FORCE_F32` (any value other than empty or `"0"`) is the
//! tier escape hatch, mirroring `RUST_PALLAS_FORCE_SCALAR` /
//! `RUST_PALLAS_FORCE_NO_COMPACT`: [`Storage::effective`] collapses
//! every tier to [`Storage::F32`], so a pinned process is bit-identical
//! to a build without the mixed-precision subsystem. The variable is
//! read once per process.

use crate::linalg::simd::wide::{bf16_from_f32, bf16_to_f32, f16_from_f32, f16_to_f32};
use crate::linalg::Matrix;
use std::sync::{Arc, OnceLock};

/// Environment variable pinning the f32 tier (escape hatch + CI matrix
/// leg). Any value other than empty or `"0"` forces f32.
pub const FORCE_F32_ENV: &str = "RUST_PALLAS_FORCE_F32";

static FORCE_F32: OnceLock<bool> = OnceLock::new();

/// True when [`FORCE_F32_ENV`] pins the f32 tier. Read once per process
/// (cached), like the no-compact hatch: tier selection happens at index
/// build time, so mid-process env flips must not split an index's
/// tiers.
pub fn force_f32_requested() -> bool {
    *FORCE_F32.get_or_init(|| match std::env::var(FORCE_F32_ENV) {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    })
}

/// Dataset storage tier: how the indexed vectors are laid out for the
/// bandit's sampling reads. `F32` is the exact (and default) tier; the
/// compressed tiers trade per-read precision for memory bandwidth and
/// are always paired with an f32 confirm pass by the query path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Storage {
    /// Exact single-precision rows (the seed behavior).
    #[default]
    F32,
    /// IEEE binary16: 2 bytes/coord, ~3 decimal digits, hardware
    /// widening via F16C / AVX-512.
    F16,
    /// bfloat16 (truncated f32): 2 bytes/coord, f32's dynamic range,
    /// 8-bit mantissa; widening is an integer shift on every ISA.
    Bf16,
    /// Signed 8-bit codes with one f32 scale per row: 1 byte/coord.
    Int8,
}

impl Storage {
    /// Bytes streamed per coordinate on this tier (the bandwidth lever;
    /// benches emit this next to their timings).
    pub fn bytes_per_coord(self) -> usize {
        match self {
            Storage::F32 => 4,
            Storage::F16 | Storage::Bf16 => 2,
            Storage::Int8 => 1,
        }
    }

    /// Stable lowercase label for logs, bench rows, and response
    /// reporting.
    pub fn label(self) -> &'static str {
        match self {
            Storage::F32 => "f32",
            Storage::F16 => "f16",
            Storage::Bf16 => "bf16",
            Storage::Int8 => "int8",
        }
    }

    /// Parse a [`Self::label`] back to a tier (`None` for unknown
    /// text). The wire layer uses this for the per-request `storage`
    /// override in both codecs.
    pub fn from_label(s: &str) -> Option<Storage> {
        match s {
            "f32" => Some(Storage::F32),
            "f16" => Some(Storage::F16),
            "bf16" => Some(Storage::Bf16),
            "int8" => Some(Storage::Int8),
            _ => None,
        }
    }

    /// The tier actually used once the process-wide
    /// [`FORCE_F32_ENV`] pin is applied.
    pub fn effective(self) -> Storage {
        self.effective_with(force_f32_requested())
    }

    /// Pin policy, exposed for tests: `force_f32` collapses every tier
    /// to [`Storage::F32`] exactly like the env var does (the env var
    /// is consulted by [`Storage::effective`], not here, so tests can
    /// exercise both branches in-process).
    pub fn effective_with(self, force_f32: bool) -> Storage {
        if force_f32 {
            Storage::F32
        } else {
            self
        }
    }
}

/// The compressed codes of one tier. `u16` payloads are f16 or bf16
/// bit patterns depending on the variant; int8 carries one f32 scale
/// per row (`value ≈ code · scale`).
#[derive(Clone, Debug)]
enum QuantData {
    F16(Arc<Vec<u16>>),
    Bf16(Arc<Vec<u16>>),
    Int8 {
        codes: Arc<Vec<i8>>,
        scales: Arc<Vec<f32>>,
    },
}

/// A row-major compressed copy of a [`Matrix`] with per-row recorded
/// quantization error — the sampling tier of a two-tier index. Cheap to
/// clone (the code buffers are shared).
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    rows: usize,
    cols: usize,
    storage: Storage,
    data: QuantData,
    /// `row_err[i] = max_j |deq(code_ij) − v_ij|`: the per-row bound
    /// the two-tier query path inflates its elimination ε by.
    row_err: Vec<f32>,
    /// `max(row_err)` — the uniform bound used when one number must
    /// cover every arm.
    max_err: f32,
    /// Per-column max |dequantized value|: the compressed tier's own
    /// reward-range bound (computed over *dequantized* values so the
    /// range covers exactly what the bandit reads).
    colmax: Vec<f32>,
}

impl QuantMatrix {
    /// Compress `m` onto `storage`, recording per-row max
    /// dequantization error and the dequantized per-column range.
    ///
    /// int8 uses a symmetric per-row scale `maxabs/127` (an all-zero
    /// row gets scale 0 and exact codes). Round-to-nearest-even for the
    /// float formats, round-half-away for int8 codes — both errors are
    /// *measured* after the fact rather than trusted from theory, so
    /// the recorded bounds are exact for the data at hand.
    ///
    /// # Panics
    /// If `storage` is [`Storage::F32`] — the exact tier has no
    /// compressed representation; gate on `storage.effective()` first.
    pub fn quantize(m: &Matrix, storage: Storage) -> QuantMatrix {
        assert!(
            storage != Storage::F32,
            "QuantMatrix::quantize: F32 is the uncompressed tier"
        );
        let (rows, cols) = (m.rows(), m.cols());
        let mut row_err = vec![0f32; rows];
        let mut colmax = vec![0f32; cols];
        let mut track = |i: usize, j: usize, orig: f32, deq: f32| {
            let err = (deq - orig).abs();
            if err > row_err[i] {
                row_err[i] = err;
            }
            if deq.abs() > colmax[j] {
                colmax[j] = deq.abs();
            }
        };
        let data = match storage {
            Storage::F32 => unreachable!(),
            Storage::F16 => {
                let mut codes = Vec::with_capacity(rows * cols);
                for i in 0..rows {
                    for (j, &v) in m.row(i).iter().enumerate() {
                        let c = f16_from_f32(v);
                        track(i, j, v, f16_to_f32(c));
                        codes.push(c);
                    }
                }
                QuantData::F16(Arc::new(codes))
            }
            Storage::Bf16 => {
                let mut codes = Vec::with_capacity(rows * cols);
                for i in 0..rows {
                    for (j, &v) in m.row(i).iter().enumerate() {
                        let c = bf16_from_f32(v);
                        track(i, j, v, bf16_to_f32(c));
                        codes.push(c);
                    }
                }
                QuantData::Bf16(Arc::new(codes))
            }
            Storage::Int8 => {
                let mut codes = Vec::with_capacity(rows * cols);
                let mut scales = Vec::with_capacity(rows);
                for i in 0..rows {
                    let row = m.row(i);
                    let maxabs = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
                    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 0.0 };
                    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
                    for (j, &v) in row.iter().enumerate() {
                        let c = (v * inv).round().clamp(-127.0, 127.0) as i8;
                        track(i, j, v, c as f32 * scale);
                        codes.push(c);
                    }
                    scales.push(scale);
                }
                QuantData::Int8 { codes: Arc::new(codes), scales: Arc::new(scales) }
            }
        };
        let max_err = row_err.iter().fold(0f32, |m, &e| m.max(e));
        QuantMatrix { rows, cols, storage, data, row_err, max_err, colmax }
    }

    /// Number of rows (arms).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row length (coordinates / pulls per arm).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The tier these codes are stored on (never [`Storage::F32`]).
    pub fn storage(&self) -> Storage {
        self.storage
    }

    /// Full f16/bf16 code buffer (row-major).
    ///
    /// # Panics
    /// On the int8 tier.
    pub fn codes_u16(&self) -> &[u16] {
        match &self.data {
            QuantData::F16(c) | QuantData::Bf16(c) => c,
            QuantData::Int8 { .. } => panic!("codes_u16 on int8 tier"),
        }
    }

    /// Full int8 code buffer (row-major).
    ///
    /// # Panics
    /// On the f16/bf16 tiers.
    pub fn codes_i8(&self) -> &[i8] {
        match &self.data {
            QuantData::Int8 { codes, .. } => codes,
            _ => panic!("codes_i8 on float tier"),
        }
    }

    /// One row of f16/bf16 codes.
    pub fn row_u16(&self, i: usize) -> &[u16] {
        &self.codes_u16()[i * self.cols..(i + 1) * self.cols]
    }

    /// One row of int8 codes.
    pub fn row_i8(&self, i: usize) -> &[i8] {
        &self.codes_i8()[i * self.cols..(i + 1) * self.cols]
    }

    /// Per-row int8 scales (`value ≈ code · scale`).
    ///
    /// # Panics
    /// On the f16/bf16 tiers.
    pub fn scales(&self) -> &[f32] {
        match &self.data {
            QuantData::Int8 { scales, .. } => scales,
            _ => panic!("scales on float tier"),
        }
    }

    /// Row `i`'s int8 scale.
    pub fn scale(&self, i: usize) -> f32 {
        self.scales()[i]
    }

    /// Recorded max |dequantized − original| of row `i`.
    pub fn row_err(&self, i: usize) -> f32 {
        self.row_err[i]
    }

    /// Max of [`QuantMatrix::row_err`] over all rows.
    pub fn max_err(&self) -> f32 {
        self.max_err
    }

    /// Per-column max |dequantized value| — the compressed tier's
    /// reward-range fold input (the analog of the f32 index's colmax).
    pub fn colmax(&self) -> &[f32] {
        &self.colmax
    }

    /// Dequantize one element (reference path for tests and the bandit's
    /// single-coordinate `pull_iid`).
    pub fn dequantize(&self, i: usize, j: usize) -> f32 {
        match &self.data {
            QuantData::F16(c) => f16_to_f32(c[i * self.cols + j]),
            QuantData::Bf16(c) => bf16_to_f32(c[i * self.cols + j]),
            QuantData::Int8 { codes, scales } => {
                codes[i * self.cols + j] as f32 * scales[i]
            }
        }
    }

    /// Dequantize a full row into a fresh vector (test/diagnostic path;
    /// the hot paths widen in registers instead).
    pub fn dequantize_row(&self, i: usize) -> Vec<f32> {
        (0..self.cols).map(|j| self.dequantize(i, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn gaussian_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.gaussian() as f32)
    }

    #[test]
    fn round_trip_error_is_recorded_exactly_and_bounded() {
        let m = gaussian_matrix(23, 97, 0xC0DE);
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&m, storage);
            assert_eq!(qm.rows(), 23);
            assert_eq!(qm.cols(), 97);
            assert_eq!(qm.storage(), storage);
            let mut global = 0f32;
            for i in 0..qm.rows() {
                let row = m.row(i);
                let maxabs = row.iter().fold(0f32, |a, &v| a.max(v.abs()));
                let mut worst = 0f32;
                for j in 0..qm.cols() {
                    let err = (qm.dequantize(i, j) - row[j]).abs();
                    // Recorded per-row bound covers every element…
                    assert!(err <= qm.row_err(i), "{storage:?} row {i} col {j}");
                    worst = worst.max(err);
                }
                // …and is tight (it IS the max, not an over-estimate).
                assert_eq!(worst, qm.row_err(i), "{storage:?} row {i}");
                global = global.max(worst);
                // Theoretical format bounds: f16 ≈ 2^-11, bf16 ≈ 2^-8
                // relative (half-ulp, slackened 2× for exponent-bucket
                // edges), int8 = half a code step.
                let theory = match storage {
                    Storage::F16 => maxabs * 2f32.powi(-10),
                    Storage::Bf16 => maxabs * 2f32.powi(-7),
                    Storage::Int8 => maxabs / 127.0 * 0.5 + 1e-6,
                    Storage::F32 => unreachable!(),
                };
                assert!(
                    qm.row_err(i) <= theory,
                    "{storage:?} row {i}: err {} vs theory {theory}",
                    qm.row_err(i)
                );
            }
            assert_eq!(global, qm.max_err(), "{storage:?} max_err");
        }
    }

    #[test]
    fn colmax_bounds_every_dequantized_element() {
        let m = gaussian_matrix(17, 64, 0xFACE);
        for storage in [Storage::F16, Storage::Bf16, Storage::Int8] {
            let qm = QuantMatrix::quantize(&m, storage);
            for i in 0..qm.rows() {
                for j in 0..qm.cols() {
                    assert!(
                        qm.dequantize(i, j).abs() <= qm.colmax()[j],
                        "{storage:?} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_zero_row_is_exact() {
        let mut m = gaussian_matrix(3, 16, 7);
        m = Matrix::from_fn(3, 16, |i, j| if i == 1 { 0.0 } else { m.row(i)[j] });
        let qm = QuantMatrix::quantize(&m, Storage::Int8);
        assert_eq!(qm.scale(1), 0.0);
        assert_eq!(qm.row_err(1), 0.0);
        assert!(qm.row_i8(1).iter().all(|&c| c == 0));
        assert_eq!(qm.dequantize_row(1), vec![0.0; 16]);
    }

    #[test]
    fn int8_codes_saturate_at_127() {
        let m = Matrix::from_fn(1, 4, |_, j| [1.0f32, -1.0, 0.5, 0.0][j]);
        let qm = QuantMatrix::quantize(&m, Storage::Int8);
        assert_eq!(qm.row_i8(0), &[127, -127, 64, 0]);
        // Scale reconstructs the max element exactly.
        assert_eq!(qm.dequantize(0, 0), 1.0);
    }

    #[test]
    fn storage_metadata_and_pin_policy() {
        assert_eq!(Storage::F32.bytes_per_coord(), 4);
        assert_eq!(Storage::F16.bytes_per_coord(), 2);
        assert_eq!(Storage::Bf16.bytes_per_coord(), 2);
        assert_eq!(Storage::Int8.bytes_per_coord(), 1);
        assert_eq!(Storage::default(), Storage::F32);
        for s in [Storage::F32, Storage::F16, Storage::Bf16, Storage::Int8] {
            // The pin collapses every tier to f32; unpinned is identity.
            assert_eq!(s.effective_with(true), Storage::F32);
            assert_eq!(s.effective_with(false), s);
            assert!(!s.label().is_empty());
        }
        // When CI's f32 leg pinned the process, effective() must honor it.
        if force_f32_requested() {
            assert_eq!(Storage::Int8.effective(), Storage::F32);
        }
    }

    #[test]
    fn quant_matrix_clones_share_codes() {
        let m = gaussian_matrix(8, 32, 3);
        let qm = QuantMatrix::quantize(&m, Storage::F16);
        let cl = qm.clone();
        assert!(std::ptr::eq(qm.codes_u16().as_ptr(), cl.codes_u16().as_ptr()));
    }
}
