//! Synthetic datasets of the paper's Figures 2–3 (Gaussian and uniform
//! coordinate distributions), plus a correlated low-rank variant used by
//! the ablation benches to stress non-i.i.d. coordinates.

use super::{Dataset, QueryKind};
use crate::linalg::{Matrix, Rng};

/// i.i.d. standard-Gaussian coordinates (`n × dim`), Figure 2's data.
pub fn gaussian_dataset(n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let vectors = Matrix::from_fn(n, dim, |_, _| rng.gaussian() as f32);
    Dataset { name: "gaussian".into(), vectors, seed, query_kind: QueryKind::Gaussian }
}

/// i.i.d. uniform `[-1, 1)` coordinates, Figure 3's data.
pub fn uniform_dataset(n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let vectors = Matrix::from_fn(n, dim, |_, _| rng.uniform(-1.0, 1.0) as f32);
    Dataset { name: "uniform".into(), vectors, seed, query_kind: QueryKind::Uniform }
}

/// Low-rank + noise data: `V = A·B + σ·E` with `A ∈ n×r`, `B ∈ r×dim`.
/// Coordinates are strongly correlated across items — the hard case for
/// coordinate-sampling methods and the motivation for random pull
/// orders (ablation `ablation_bounds`).
pub fn low_rank_dataset(n: usize, dim: usize, rank: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(n, rank, |_, _| rng.gaussian() as f32);
    let b = Matrix::from_fn(rank, dim, |_, _| rng.gaussian() as f32);
    let scale = 1.0 / (rank as f32).sqrt();
    let vectors = Matrix::from_fn(n, dim, |i, j| {
        let mut s = 0f32;
        for r in 0..rank {
            s += a.get(i, r) * b.get(r, j);
        }
        s * scale + noise * rng.gaussian() as f32
    });
    Dataset { name: "low-rank".into(), vectors, seed, query_kind: QueryKind::Gaussian }
}

/// A "spiky" adversarial-ish MIPS dataset: most mass uniform, but a few
/// items carry one huge coordinate, the case where GREEDY-MIPS's
/// screening is claimed to degrade (Table 1 "Notes" column).
pub fn spiky_dataset(n: usize, dim: usize, n_spikes: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut vectors = Matrix::from_fn(n, dim, |_, _| rng.uniform(-0.1, 0.1) as f32);
    // Re-build with spikes: all items share the same large first
    // coordinate (so the largest coordinate of q^T v is identical for all
    // v — the paper's note), while true ranking is decided elsewhere.
    let mut data = vectors.as_slice().to_vec();
    for i in 0..n {
        data[i * dim] = 1.0;
    }
    for s in 0..n_spikes.min(n) {
        let item = rng.next_below(n);
        let coord = 1 + rng.next_below(dim - 1);
        data[item * dim + coord] = 0.9 + 0.1 * (s as f32 / n_spikes.max(1) as f32);
    }
    vectors = Matrix::from_vec(n, dim, data);
    Dataset { name: "spiky".into(), vectors, seed, query_kind: QueryKind::Uniform }
}

/// Gaussian-mixture data: `n_clusters` centers with per-cluster spread.
/// The geometry LSH/PCA-trees are *good* at (tight clusters ⇒ informative
/// partitions) — used by the ablations to map where each baseline wins.
pub fn clustered_dataset(
    n: usize,
    dim: usize,
    n_clusters: usize,
    spread: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let n_clusters = n_clusters.max(1);
    let centers: Vec<Vec<f32>> =
        (0..n_clusters).map(|_| rng.gaussian_vec(dim)).collect();
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let c = &centers[rng.next_below(n_clusters)];
        let mut row = rng.gaussian_vec(dim);
        for (x, &m) in row.iter_mut().zip(c) {
            *x = m + spread * *x;
        }
        rows.push(row);
    }
    Dataset {
        name: format!("clustered-{n_clusters}"),
        vectors: Matrix::from_rows(&rows),
        seed,
        query_kind: QueryKind::Gaussian,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_shape_and_moments() {
        let ds = gaussian_dataset(200, 64, 1);
        assert_eq!((ds.n(), ds.dim()), (200, 64));
        let all = ds.vectors.as_slice();
        let mean: f32 = all.iter().sum::<f32>() / all.len() as f32;
        let var: f32 = all.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / all.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn uniform_in_range() {
        let ds = uniform_dataset(50, 32, 2);
        assert!(ds.vectors.as_slice().iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn low_rank_is_correlated() {
        let ds = low_rank_dataset(100, 64, 2, 0.0, 3);
        // Rank-2 data: any 3 rows are linearly dependent; check via the
        // Gram determinant of 3 random rows being ~0 relative to scale.
        let r0 = ds.vectors.row(0);
        let r1 = ds.vectors.row(1);
        let r2 = ds.vectors.row(2);
        let g = |a: &[f32], b: &[f32]| crate::linalg::dot(a, b) as f64;
        let det = g(r0, r0) * (g(r1, r1) * g(r2, r2) - g(r1, r2) * g(r1, r2))
            - g(r0, r1) * (g(r0, r1) * g(r2, r2) - g(r1, r2) * g(r0, r2))
            + g(r0, r2) * (g(r0, r1) * g(r1, r2) - g(r1, r1) * g(r0, r2));
        let scale = g(r0, r0) * g(r1, r1) * g(r2, r2);
        assert!(det.abs() / scale.max(1e-12) < 1e-3, "det ratio = {}", det / scale);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gaussian_dataset(10, 10, 7);
        let b = gaussian_dataset(10, 10, 7);
        assert_eq!(a.vectors, b.vectors);
    }

    #[test]
    fn spiky_has_identical_first_coordinate() {
        let ds = spiky_dataset(40, 16, 5, 9);
        for i in 0..40 {
            assert_eq!(ds.vectors.get(i, 0), 1.0);
        }
    }

    #[test]
    fn clustered_points_hug_centers() {
        let ds = clustered_dataset(300, 24, 4, 0.05, 11);
        assert_eq!(ds.n(), 300);
        // With spread 0.05, points from the same cluster are far closer
        // to each other than points from different clusters on average.
        // Proxy check: the global variance per coordinate stays ~1 (from
        // the centers) while nearest-neighbor distances are tiny.
        let d01 = crate::linalg::dist_sq(ds.vectors.row(0), ds.vectors.row(1));
        let mut min_d = f32::INFINITY;
        for j in 1..100 {
            min_d = min_d.min(crate::linalg::dist_sq(ds.vectors.row(0), ds.vectors.row(j)));
        }
        assert!(min_d < d01.max(1e-6) * 10.0 + 1e3); // smoke: finite, sane
        assert!(min_d < 24.0 * 0.05 * 0.05 * 40.0, "no close neighbor found: {min_d}");
    }

    #[test]
    fn clustered_single_cluster_ok() {
        let ds = clustered_dataset(20, 8, 1, 0.1, 3);
        assert_eq!(ds.n(), 20);
    }
}
