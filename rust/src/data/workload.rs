//! Query-workload generation for the serving benches: Poisson arrivals,
//! mixed per-query accuracy requirements, and trace replay.

use super::Dataset;
use crate::linalg::Rng;

/// One query in a serving trace.
#[derive(Clone, Debug)]
pub struct TraceQuery {
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    /// The query vector.
    pub vector: Vec<f32>,
    /// Requested result count.
    pub k: usize,
    /// Requested suboptimality ε (BOUNDEDME knob).
    pub epsilon: f64,
    /// Requested confidence δ.
    pub delta: f64,
}

/// Workload shape parameters.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Mean arrival rate, queries/second.
    pub rate: f64,
    /// Number of queries in the trace.
    pub count: usize,
    /// Result count per query.
    pub k: usize,
    /// (ε, δ) tiers with selection weights — models a mixed tenancy where
    /// some queries want tight guarantees and some want speed.
    pub tiers: Vec<(f64, f64, f64)>, // (epsilon, delta, weight)
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            rate: 200.0,
            count: 1000,
            k: 10,
            tiers: vec![(0.05, 0.05, 0.2), (0.1, 0.1, 0.5), (0.3, 0.2, 0.3)],
            seed: 0,
        }
    }
}

/// Generate a Poisson-arrival trace of queries over a dataset.
pub fn poisson_trace(ds: &Dataset, cfg: &WorkloadConfig) -> Vec<TraceQuery> {
    let mut rng = Rng::new(cfg.seed ^ 0xF00D);
    let total_w: f64 = cfg.tiers.iter().map(|t| t.2).sum();
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.count);
    for i in 0..cfg.count {
        t += rng.exponential(cfg.rate.max(1e-9));
        // Pick a tier by weight.
        let mut pick = rng.next_f64() * total_w;
        let mut tier = cfg.tiers.last().copied().unwrap_or((0.1, 0.1, 1.0));
        for &(e, d, w) in &cfg.tiers {
            if pick < w {
                tier = (e, d, w);
                break;
            }
            pick -= w;
        }
        out.push(TraceQuery {
            arrival: t,
            vector: ds.sample_query(cfg.seed.wrapping_add(i as u64 * 104729)),
            k: cfg.k,
            epsilon: tier.0,
            delta: tier.1,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn trace_shape() {
        let ds = gaussian_dataset(10, 16, 1);
        let cfg = WorkloadConfig { count: 100, rate: 1000.0, ..Default::default() };
        let trace = poisson_trace(&ds, &cfg);
        assert_eq!(trace.len(), 100);
        // Arrivals strictly increasing.
        for w in trace.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
        // Mean inter-arrival ≈ 1/rate.
        let span = trace.last().unwrap().arrival;
        assert!((span / 100.0 - 1e-3).abs() < 5e-4, "span={span}");
    }

    #[test]
    fn tiers_all_appear() {
        let ds = gaussian_dataset(10, 8, 2);
        let cfg = WorkloadConfig { count: 300, ..Default::default() };
        let trace = poisson_trace(&ds, &cfg);
        for &(e, _, _) in &cfg.tiers {
            assert!(
                trace.iter().any(|q| (q.epsilon - e).abs() < 1e-12),
                "tier ε={e} never drawn"
            );
        }
    }

    #[test]
    fn deterministic() {
        let ds = gaussian_dataset(5, 8, 3);
        let cfg = WorkloadConfig { count: 20, ..Default::default() };
        let a = poisson_trace(&ds, &cfg);
        let b = poisson_trace(&ds, &cfg);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[7].vector, b[7].vector);
        assert_eq!(a[7].arrival, b[7].arrival);
    }
}
