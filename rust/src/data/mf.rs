//! Matrix-factorization substrate: the "real-world" datasets of
//! Figure 4, rebuilt from first principles.
//!
//! The paper evaluates on Netflix and Yahoo-Music item embeddings
//! produced by matrix factorization (following Yu et al. 2017). We do
//! not have the raw rating data, so this module implements the full
//! pipeline on a *synthetic* rating matrix with the same shape
//! characteristics (Zipf-skewed item popularity, low-rank user taste):
//!
//! 1. [`generate_implicit_ratings`] — synthetic implicit feedback from a
//!    ground-truth low-rank preference model + popularity skew;
//! 2. [`als_implicit`] — implicit-feedback ALS (Hu, Koren & Volinsky
//!    2008), the standard recommender factorization;
//! 3. [`lift_embeddings`] — an inner-product-preserving random
//!    orthonormal lift of the rank-`r` factors into `R^dim`, giving the
//!    high-dimensional vectors the MIPS experiments need. Inner products
//!    (and therefore the entire MIPS problem: winners, gaps, precision)
//!    are *identical* before and after the lift.
//!
//! Presets [`netflix_like`] and [`yahoo_like`] bundle the pipeline with
//! shape parameters mimicking each dataset.

use super::{Dataset, QueryKind};
use crate::linalg::solve::{cholesky_solve, random_orthonormal};
use crate::linalg::{Matrix, Rng};

/// Sparse implicit-feedback ratings in CSR-like form.
#[derive(Clone, Debug)]
pub struct RatingMatrix {
    /// Number of users.
    pub n_users: usize,
    /// Number of items.
    pub n_items: usize,
    /// Per-user sorted item lists.
    pub user_items: Vec<Vec<u32>>,
}

impl RatingMatrix {
    /// Total number of observed interactions.
    pub fn nnz(&self) -> usize {
        self.user_items.iter().map(|v| v.len()).sum()
    }

    /// Transpose view: per-item user lists.
    pub fn item_users(&self) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.n_items];
        for (u, items) in self.user_items.iter().enumerate() {
            for &i in items {
                out[i as usize].push(u as u32);
            }
        }
        out
    }
}

/// Generate synthetic implicit feedback.
///
/// Ground truth: rank-`true_rank` Gaussian user/item factors. A user's
/// interactions are drawn by sampling items from a Zipf(`zipf_s`)
/// popularity law and accepting with probability
/// `σ(⟨u, v⟩)` — popularity skew × personal taste, the structure that
/// makes recommender embeddings heavy-tailed.
pub fn generate_implicit_ratings(
    n_users: usize,
    n_items: usize,
    avg_per_user: usize,
    zipf_s: f64,
    true_rank: usize,
    seed: u64,
) -> RatingMatrix {
    let mut rng = Rng::new(seed);
    let scale = 1.0 / (true_rank as f64).sqrt();
    let users: Vec<Vec<f32>> =
        (0..n_users).map(|_| rng.gaussian_vec(true_rank)).collect();
    let items: Vec<Vec<f32>> =
        (0..n_items).map(|_| rng.gaussian_vec(true_rank)).collect();
    // Random popularity order (so item id ≠ popularity rank).
    let pop_order = rng.permutation(n_items);

    let mut user_items = Vec::with_capacity(n_users);
    for u in 0..n_users {
        // User activity is itself skewed: 1..=4× the average.
        let target = 1 + (avg_per_user as f64 * (0.25 + 1.5 * rng.next_f64())) as usize;
        let mut set = std::collections::BTreeSet::new();
        let mut attempts = 0;
        while set.len() < target && attempts < target * 20 {
            attempts += 1;
            let item = pop_order[rng.zipf(n_items, zipf_s)];
            let score =
                crate::linalg::dot(&users[u], &items[item]) as f64 * scale;
            let p = 1.0 / (1.0 + (-2.0 * score).exp()); // σ(2·score)
            if rng.bernoulli(p) {
                set.insert(item as u32);
            }
        }
        user_items.push(set.into_iter().collect());
    }
    RatingMatrix { n_users, n_items, user_items }
}

/// Implicit-feedback ALS factors.
#[derive(Clone, Debug)]
pub struct MfModel {
    /// `n_users × rank` user factors.
    pub user_factors: Matrix,
    /// `n_items × rank` item factors.
    pub item_factors: Matrix,
}

/// Implicit ALS (Hu–Koren–Volinsky): confidence `c = 1 + α` on observed
/// cells, preference 1/0; alternating ridge solves via Cholesky.
///
/// Uses the standard `(YᵀY + Yᵀ(C−I)Y + λI) x = Yᵀ C p` normal
/// equations with the `YᵀY` Gram precomputed once per half-sweep.
pub fn als_implicit(
    ratings: &RatingMatrix,
    rank: usize,
    iters: usize,
    reg: f64,
    alpha: f64,
    seed: u64,
) -> MfModel {
    let mut rng = Rng::new(seed);
    let init = |n: usize, rng: &mut Rng| -> Vec<Vec<f64>> {
        (0..n)
            .map(|_| (0..rank).map(|_| rng.gaussian() * 0.1).collect())
            .collect()
    };
    let mut u_f = init(ratings.n_users, &mut rng);
    let mut i_f = init(ratings.n_items, &mut rng);
    let item_users = ratings.item_users();

    let solve_side = |solve_for: &mut Vec<Vec<f64>>,
                      fixed: &Vec<Vec<f64>>,
                      lists: &[Vec<u32>]| {
        // Gram = fixedᵀ fixed (rank × rank).
        let mut gram = vec![0.0f64; rank * rank];
        for f in fixed {
            for a in 0..rank {
                for b in a..rank {
                    gram[a * rank + b] += f[a] * f[b];
                }
            }
        }
        for a in 0..rank {
            for b in 0..a {
                gram[a * rank + b] = gram[b * rank + a];
            }
        }
        for (x, list) in solve_for.iter_mut().zip(lists) {
            // A = Gram + α Σ_{j∈list} y_j y_jᵀ + λI ; b = (1+α) Σ y_j.
            let mut a_mat = gram.clone();
            let mut b = vec![0.0f64; rank];
            for &j in list {
                let y = &fixed[j as usize];
                for r in 0..rank {
                    b[r] += (1.0 + alpha) * y[r];
                    for c in 0..rank {
                        a_mat[r * rank + c] += alpha * y[r] * y[c];
                    }
                }
            }
            for r in 0..rank {
                a_mat[r * rank + r] += reg;
            }
            if cholesky_solve(&mut a_mat, &mut b, rank) {
                *x = b;
            }
        }
    };

    for _ in 0..iters {
        solve_side(&mut u_f, &i_f, &ratings.user_items);
        solve_side(&mut i_f, &u_f, &item_users);
    }

    let to_matrix = |f: Vec<Vec<f64>>| {
        Matrix::from_rows(
            &f.into_iter()
                .map(|r| r.into_iter().map(|x| x as f32).collect::<Vec<f32>>())
                .collect::<Vec<_>>(),
        )
    };
    MfModel { user_factors: to_matrix(u_f), item_factors: to_matrix(i_f) }
}

/// Lift rank-`r` factors into `R^dim` with a shared random orthonormal
/// frame `E` (`r × dim`, `E Eᵀ = I`): `v ↦ Eᵀ v`. Inner products are
/// preserved exactly, so the MIPS instance is unchanged — only the
/// ambient dimension grows to the experiment's scale.
pub fn lift_embeddings(factors: &Matrix, dim: usize, seed: u64) -> Matrix {
    let rank = factors.cols();
    assert!(dim >= rank, "lift target dim {dim} < rank {rank}");
    let e = random_orthonormal(rank, dim, seed); // rank × dim
    Matrix::from_fn(factors.rows(), dim, |i, j| {
        let row = factors.row(i);
        let mut s = 0f32;
        for r in 0..rank {
            s += row[r] * e[r * dim + j];
        }
        s
    })
}

/// A Figure-4 dataset: lifted item embeddings plus genuine user-factor
/// queries from the same factorization.
#[derive(Clone, Debug)]
pub struct MfDataset {
    /// The MIPS instance over item embeddings.
    pub dataset: Dataset,
    /// Lifted user factors — the natural query distribution for
    /// recommender MIPS.
    pub user_queries: Vec<Vec<f32>>,
}

/// Run the whole pipeline with the given shape.
#[allow(clippy::too_many_arguments)]
pub fn mf_dataset(
    name: &str,
    n_users: usize,
    n_items: usize,
    avg_per_user: usize,
    zipf_s: f64,
    rank: usize,
    dim: usize,
    seed: u64,
) -> MfDataset {
    let ratings =
        generate_implicit_ratings(n_users, n_items, avg_per_user, zipf_s, rank, seed);
    let model = als_implicit(&ratings, rank, 8, 0.05, 20.0, seed ^ 0xA5A5);
    let items = lift_embeddings(&model.item_factors, dim, seed ^ 0x5A5A);
    let users = lift_embeddings(&model.user_factors, dim, seed ^ 0x5A5A);
    let user_queries = (0..users.rows()).map(|i| users.row(i).to_vec()).collect();
    MfDataset {
        dataset: Dataset {
            name: name.into(),
            vectors: items,
            seed,
            query_kind: QueryKind::UserFactor,
        },
        user_queries,
    }
}

/// Netflix-shaped preset (movies ≫ users sampled here; rank 32).
pub fn netflix_like(n_items: usize, dim: usize, seed: u64) -> MfDataset {
    let n_users = (n_items / 4).max(32);
    mf_dataset("netflix-like", n_users, n_items, 24, 1.1, 32, dim, seed)
}

/// Yahoo-Music-shaped preset (heavier skew, rank 48).
pub fn yahoo_like(n_items: usize, dim: usize, seed: u64) -> MfDataset {
    let n_users = (n_items / 3).max(32);
    mf_dataset("yahoo-like", n_users, n_items, 40, 1.4, 48, dim, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratings_have_skewed_popularity() {
        let r = generate_implicit_ratings(200, 300, 12, 1.3, 8, 1);
        assert_eq!(r.n_users, 200);
        assert!(r.nnz() > 200, "nnz={}", r.nnz());
        // Popularity skew: the busiest item should dwarf the median.
        let counts: Vec<usize> = r.item_users().iter().map(|v| v.len()).collect();
        let max = *counts.iter().max().unwrap();
        let mut sorted = counts.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2];
        assert!(max >= median.max(1) * 4, "max={max} median={median}");
    }

    #[test]
    fn als_reconstructs_preferences() {
        // ALS factors should rank a user's observed items above random
        // unobserved ones on average.
        let r = generate_implicit_ratings(120, 150, 15, 1.1, 8, 2);
        let m = als_implicit(&r, 16, 6, 0.05, 20.0, 3);
        let mut better = 0;
        let mut total = 0;
        let mut rng = Rng::new(4);
        for u in 0..120 {
            let uf = m.user_factors.row(u);
            for &obs in r.user_items[u].iter().take(3) {
                let s_obs = crate::linalg::dot(uf, m.item_factors.row(obs as usize));
                let rand_item = rng.next_below(150);
                if r.user_items[u].contains(&(rand_item as u32)) {
                    continue;
                }
                let s_rand = crate::linalg::dot(uf, m.item_factors.row(rand_item));
                total += 1;
                if s_obs > s_rand {
                    better += 1;
                }
            }
        }
        assert!(total > 50);
        let frac = better as f64 / total as f64;
        assert!(frac > 0.8, "observed-ranked-higher fraction = {frac}");
    }

    #[test]
    fn lift_preserves_inner_products() {
        let mut rng = Rng::new(5);
        let f = Matrix::from_fn(20, 8, |_, _| rng.gaussian() as f32);
        let lifted = lift_embeddings(&f, 64, 6);
        assert_eq!((lifted.rows(), lifted.cols()), (20, 64));
        for i in 0..20 {
            for j in 0..20 {
                let orig = crate::linalg::dot(f.row(i), f.row(j));
                let big = crate::linalg::dot(lifted.row(i), lifted.row(j));
                assert!((orig - big).abs() < 1e-3, "({i},{j}): {orig} vs {big}");
            }
        }
    }

    #[test]
    fn presets_produce_well_formed_datasets() {
        let ds = netflix_like(60, 128, 7);
        assert_eq!(ds.dataset.n(), 60);
        assert_eq!(ds.dataset.dim(), 128);
        assert!(!ds.user_queries.is_empty());
        assert_eq!(ds.user_queries[0].len(), 128);
        assert!(ds.dataset.vectors.as_slice().iter().all(|x| x.is_finite()));
    }
}
