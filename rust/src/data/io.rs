//! Binary dataset (de)serialization.
//!
//! Format (little-endian):
//! ```text
//! magic  u64  = 0x424D495053563031 ("BMIPSV01")
//! rows   u64
//! cols   u64
//! seed   u64
//! kind   u8   (0 = Gaussian, 1 = Uniform, 2 = UserFactor)
//! nlen   u16  name length
//! name   [u8; nlen]
//! data   [f32; rows·cols]
//! ```

use super::{Dataset, QueryKind};
use crate::linalg::Matrix;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: u64 = 0x424D_4950_5356_3031;

/// Serialize a dataset to a writer.
pub fn write_dataset<W: Write>(ds: &Dataset, w: &mut W) -> std::io::Result<()> {
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&(ds.vectors.rows() as u64).to_le_bytes())?;
    w.write_all(&(ds.vectors.cols() as u64).to_le_bytes())?;
    w.write_all(&ds.seed.to_le_bytes())?;
    let kind: u8 = match ds.query_kind {
        QueryKind::Gaussian => 0,
        QueryKind::Uniform => 1,
        QueryKind::UserFactor => 2,
    };
    w.write_all(&[kind])?;
    let name = ds.name.as_bytes();
    let nlen = name.len().min(u16::MAX as usize) as u16;
    w.write_all(&nlen.to_le_bytes())?;
    w.write_all(&name[..nlen as usize])?;
    // Bulk f32 write.
    let floats = ds.vectors.as_slice();
    let bytes = unsafe {
        std::slice::from_raw_parts(floats.as_ptr() as *const u8, floats.len() * 4)
    };
    w.write_all(bytes)
}

/// Deserialize a dataset from a reader.
pub fn read_dataset<R: Read>(r: &mut R) -> std::io::Result<Dataset> {
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut R| -> std::io::Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let magic = read_u64(r)?;
    if magic != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad magic {magic:#x}"),
        ));
    }
    let rows = read_u64(r)? as usize;
    let cols = read_u64(r)? as usize;
    let seed = read_u64(r)?;
    let mut kind_buf = [0u8; 1];
    r.read_exact(&mut kind_buf)?;
    let query_kind = match kind_buf[0] {
        0 => QueryKind::Gaussian,
        1 => QueryKind::Uniform,
        2 => QueryKind::UserFactor,
        k => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad query kind {k}"),
            ))
        }
    };
    let mut nlen_buf = [0u8; 2];
    r.read_exact(&mut nlen_buf)?;
    let nlen = u16::from_le_bytes(nlen_buf) as usize;
    let mut name_buf = vec![0u8; nlen];
    r.read_exact(&mut name_buf)?;
    let name = String::from_utf8_lossy(&name_buf).into_owned();

    let count = rows
        .checked_mul(cols)
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "overflow"))?;
    let mut data = vec![0f32; count];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
    };
    r.read_exact(bytes)?;
    Ok(Dataset { name, vectors: Matrix::from_vec(rows, cols, data), seed, query_kind })
}

/// Save to a file path.
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_dataset(ds, &mut f)
}

/// Load from a file path.
pub fn load(path: impl AsRef<Path>) -> std::io::Result<Dataset> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_dataset(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn roundtrip_in_memory() {
        let ds = gaussian_dataset(13, 7, 99);
        let mut buf = Vec::new();
        write_dataset(&ds, &mut buf).unwrap();
        let back = read_dataset(&mut buf.as_slice()).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.seed, 99);
        assert_eq!(back.query_kind, ds.query_kind);
        assert_eq!(back.vectors, ds.vectors);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = vec![0u8; 64];
        assert!(read_dataset(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn roundtrip_file() {
        let ds = gaussian_dataset(4, 4, 1);
        let dir = std::env::temp_dir().join("bandit_mips_io_test.bin");
        save(&ds, &dir).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.vectors, ds.vectors);
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn truncated_input_errors() {
        let ds = gaussian_dataset(8, 8, 2);
        let mut buf = Vec::new();
        write_dataset(&ds, &mut buf).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(read_dataset(&mut buf.as_slice()).is_err());
    }
}
