//! Experiment harness: regenerates every table and figure of the paper.
//!
//! | paper artifact | function | example binary |
//! |---|---|---|
//! | Figure 1 (guarantee validation) | [`fig1::run`] | `fig1_guarantee` |
//! | Figures 2–4 (precision vs speedup) | [`precision_speedup::run_sweep`] | `fig2_gaussian`, `fig3_uniform`, `fig4_realworld` |
//! | Table 1 (preprocessing/query complexity) | [`table1::run`] | `table1` |
//!
//! Each function returns plain row structs; the example binaries print
//! them as aligned markdown so EXPERIMENTS.md can quote them directly.

pub mod csv;
pub mod fig1;
pub mod precision_speedup;
pub mod table1;

/// Render rows of `(label, value…)` as an aligned markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
        }
        s
    };
    let mut out = fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push('\n');
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{:-<w$}|", "", w = w + 2));
    }
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(r));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn markdown_table_aligns() {
        let t = super::markdown_table(
            &["algo", "x"],
            &[vec!["BoundedME".into(), "1.5".into()], vec!["LSH".into(), "22".into()]],
        );
        assert!(t.contains("| algo"));
        assert!(t.lines().count() == 4);
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{t}");
    }
}
