//! Table 1: measured preprocessing time, query time, and guarantee
//! summary for every method on one common dataset.
//!
//! The paper's Table 1 is analytic (big-O); this harness produces its
//! measured counterpart so EXPERIMENTS.md can show both side by side.

use crate::algos::{
    BoundedMeIndex, GreedyMipsIndex, LshMipsIndex, MipsIndex, MipsParams, NaiveIndex,
    PcaMipsIndex, RptMipsIndex,
};
use crate::data::Dataset;
use crate::metrics::precision_at_k;
use std::time::Instant;

/// One measured Table-1 row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Method name.
    pub method: String,
    /// Preprocessing wall-clock seconds.
    pub prep_seconds: f64,
    /// Mean per-query wall-clock seconds.
    pub query_seconds: f64,
    /// Mean per-query flops.
    pub query_flops: f64,
    /// Mean precision@K.
    pub precision: f64,
    /// Guarantee column (verbatim from the paper's table).
    pub guarantee: &'static str,
}

/// Table-1 configuration.
#[derive(Clone, Debug)]
pub struct Table1Config {
    /// Result-set size.
    pub k: usize,
    /// Queries to average over.
    pub queries: usize,
    /// BOUNDEDME (ε, δ).
    pub epsilon: f64,
    /// BOUNDEDME δ.
    pub delta: f64,
    /// GREEDY budget fraction.
    pub greedy_budget_frac: f64,
    /// LSH (a, b).
    pub lsh: (usize, usize),
    /// PCA depth.
    pub pca_depth: usize,
    /// RPT (L, leaf).
    pub rpt: (usize, usize),
    /// Seed.
    pub seed: u64,
}

impl Default for Table1Config {
    fn default() -> Self {
        Self {
            k: 5,
            queries: 10,
            epsilon: 0.05,
            delta: 0.1,
            greedy_budget_frac: 0.3,
            lsh: (8, 16),
            pca_depth: 4,
            rpt: (8, 64),
            seed: 0,
        }
    }
}

/// Measure all methods. Indexes are built inside so preprocessing time
/// is captured.
pub fn run(ds: &Dataset, cfg: &Table1Config) -> Vec<Table1Row> {
    let queries = ds.sample_queries(cfg.queries, cfg.seed);
    let truths: Vec<Vec<usize>> = queries
        .iter()
        .map(|q| crate::algos::ground_truth(&ds.vectors, q, cfg.k))
        .collect();

    let n = ds.n();
    let mut rows = Vec::new();

    let mut ctx = crate::exec::QueryContext::new();
    let mut measure = |index: &dyn MipsIndex, guarantee: &'static str| {
        let mut flops = 0u64;
        let mut secs = 0f64;
        let mut prec = 0f64;
        for (qi, (q, truth)) in queries.iter().zip(&truths).enumerate() {
            let params = MipsParams {
                k: cfg.k,
                epsilon: cfg.epsilon,
                delta: cfg.delta,
                seed: cfg.seed ^ qi as u64,
            };
            let t = Instant::now();
            let res = index.query_with(q, &params, &mut ctx);
            secs += t.elapsed().as_secs_f64();
            flops += res.flops;
            prec += precision_at_k(truth, &res.indices);
        }
        let qn = queries.len().max(1) as f64;
        rows.push(Table1Row {
            method: index.name().to_string(),
            prep_seconds: index.preprocessing_seconds(),
            query_seconds: secs / qn,
            query_flops: flops as f64 / qn,
            precision: prec / qn,
            guarantee,
        });
    };

    measure(
        &BoundedMeIndex::new(ds.vectors.clone()),
        "ε-optimal w.p. ≥ 1−δ for any user (ε, δ)",
    );
    measure(
        &GreedyMipsIndex::new(
            ds.vectors.clone(),
            ((n as f64 * cfg.greedy_budget_frac) as usize).max(1),
        ),
        "none in general (uniform-data h.p. bound only)",
    );
    measure(
        &LshMipsIndex::new(ds.vectors.clone(), cfg.lsh.0, cfg.lsh.1, cfg.seed ^ 1),
        "prob. depends on unknown angle of v*",
    );
    measure(
        &PcaMipsIndex::new(ds.vectors.clone(), cfg.pca_depth, cfg.seed ^ 2),
        "none",
    );
    measure(
        &RptMipsIndex::new(ds.vectors.clone(), cfg.rpt.0, cfg.rpt.1, cfg.seed ^ 3),
        "potential-function bound, not controllable",
    );
    measure(&NaiveIndex::new(ds.vectors.clone()), "exact");

    rows
}

/// Render rows as markdown.
pub fn format_rows(rows: &[Table1Row]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                format!("{:.3}s", r.prep_seconds),
                format!("{:.2}ms", r.query_seconds * 1e3),
                format!("{:.2e}", r.query_flops),
                format!("{:.3}", r.precision),
                r.guarantee.to_string(),
            ]
        })
        .collect();
    super::markdown_table(
        &["method", "preprocess", "query", "query flops", "precision", "guarantee"],
        &body,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn all_methods_measured() {
        let ds = gaussian_dataset(120, 48, 1);
        let cfg = Table1Config { queries: 3, pca_depth: 3, rpt: (2, 16), ..Default::default() };
        let rows = run(&ds, &cfg);
        assert_eq!(rows.len(), 6);
        let names: Vec<&str> = rows.iter().map(|r| r.method.as_str()).collect();
        assert!(names.contains(&"BoundedME"));
        assert!(names.contains(&"Naive"));
        // BoundedME has zero preprocessing; Greedy/LSH/PCA/RPT have > 0.
        let by_name = |n: &str| rows.iter().find(|r| r.method == n).unwrap();
        assert_eq!(by_name("BoundedME").prep_seconds, 0.0);
        assert!(by_name("Greedy").prep_seconds > 0.0);
        assert!(by_name("Naive").precision > 0.999);
        let table = format_rows(&rows);
        assert!(table.contains("BoundedME"));
    }
}
