//! CSV emission for experiment results, so figure data can be plotted
//! outside the repo (gnuplot/matplotlib) and diffed across runs.

use super::fig1::Fig1Point;
use super::precision_speedup::SweepPoint;
use super::table1::Table1Row;
use std::io::Write;
use std::path::Path;

/// Escape a CSV field (quotes fields containing separators).
fn field(s: &str) -> String {
    if s.contains([',', '"', '\n']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Write rows of string cells with a header.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: impl IntoIterator<Item = Vec<String>>,
) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|c| field(c)).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Figure-1 points → CSV.
pub fn fig1_csv(path: impl AsRef<Path>, points: &[Fig1Point]) -> std::io::Result<()> {
    write_csv(
        path,
        &["epsilon", "delta", "quantile_subopt", "mean_subopt", "mean_pulls", "holds"],
        points.iter().map(|p| {
            vec![
                p.epsilon.to_string(),
                p.delta.to_string(),
                p.quantile_subopt.to_string(),
                p.mean_subopt.to_string(),
                p.mean_pulls.to_string(),
                p.holds.to_string(),
            ]
        }),
    )
}

/// Precision/speedup sweep → CSV (figures 2–4).
pub fn sweep_csv(path: impl AsRef<Path>, points: &[SweepPoint]) -> std::io::Result<()> {
    write_csv(
        path,
        &["algo", "knob", "precision", "speedup_flops", "speedup_wall", "candidates"],
        points.iter().map(|p| {
            vec![
                p.algo.clone(),
                p.knob.clone(),
                p.precision.to_string(),
                p.speedup_flops.to_string(),
                p.speedup_wall.to_string(),
                p.mean_candidates.to_string(),
            ]
        }),
    )
}

/// Table-1 rows → CSV.
pub fn table1_csv(path: impl AsRef<Path>, rows: &[Table1Row]) -> std::io::Result<()> {
    write_csv(
        path,
        &["method", "prep_seconds", "query_seconds", "query_flops", "precision", "guarantee"],
        rows.iter().map(|r| {
            vec![
                r.method.clone(),
                r.prep_seconds.to_string(),
                r.query_seconds.to_string(),
                r.query_flops.to_string(),
                r.precision.to_string(),
                r.guarantee.to_string(),
            ]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping() {
        assert_eq!(field("plain"), "plain");
        assert_eq!(field("a,b"), "\"a,b\"");
        assert_eq!(field("q\"uote"), "\"q\"\"uote\"");
    }

    #[test]
    fn writes_sweep_csv() {
        let points = vec![SweepPoint {
            algo: "X".into(),
            knob: "eps=0.1".into(),
            precision: 0.5,
            speedup_flops: 2.0,
            speedup_wall: 1.5,
            mean_candidates: 3.0,
        }];
        let path = std::env::temp_dir().join("bm_sweep_test.csv");
        sweep_csv(&path, &points).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.starts_with("algo,knob,"));
        assert!(text.contains("X,eps=0.1,0.5,2,1.5,3"));
    }

    #[test]
    fn writes_fig1_csv() {
        let p = super::super::fig1::Fig1Point {
            epsilon: 0.1,
            delta: 0.05,
            quantile_subopt: 0.01,
            mean_subopt: 0.005,
            mean_pulls: 1e4,
            holds: true,
        };
        let path = std::env::temp_dir().join("bm_fig1_test.csv");
        fig1_csv(&path, &[p]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("true"));
    }
}
