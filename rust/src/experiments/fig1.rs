//! Figure 1: empirical validation of Theorem 1 on the adversarial
//! environment.
//!
//! For each (ε, δ) pair, run BOUNDEDME `trials` times on freshly
//! generated adversarial Bernoulli arms (rewards served 1s-first) and
//! record the `(1−δ)`-percentile of the observed suboptimalities. The
//! guarantee holds iff that percentile stays below ε — in the paper's
//! plot, every point sits under the `y = x` diagonal.

use crate::bandit::{AdversarialArms, BoundedMe, BoundedMeConfig, RewardSource};

/// Configuration of the Figure-1 sweep.
#[derive(Clone, Debug)]
pub struct Fig1Config {
    /// Number of arms `n` (paper: 10⁴).
    pub n_arms: usize,
    /// Reward-list length `N` (paper: 10⁵).
    pub n_list: usize,
    /// ε grid (paper: 0…0.6).
    pub epsilons: Vec<f64>,
    /// δ grid (paper: {0.01, 0.05, 0.1, 0.2, 0.3}).
    pub deltas: Vec<f64>,
    /// Independent trials per (ε, δ) (paper: 20).
    pub trials: usize,
    /// Base seed.
    pub seed: u64,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Self {
            n_arms: 1000,
            n_list: 2000,
            epsilons: vec![0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
            deltas: vec![0.01, 0.05, 0.1, 0.2, 0.3],
            trials: 20,
            seed: 0,
        }
    }
}

/// One Figure-1 point.
#[derive(Clone, Copy, Debug)]
pub struct Fig1Point {
    /// Requested ε.
    pub epsilon: f64,
    /// Requested δ.
    pub delta: f64,
    /// `(1−δ)`-percentile of observed suboptimality across trials.
    pub quantile_subopt: f64,
    /// Mean suboptimality across trials.
    pub mean_subopt: f64,
    /// Mean pulls per trial.
    pub mean_pulls: f64,
    /// True iff `quantile_subopt ≤ epsilon` (the guarantee).
    pub holds: bool,
}

/// Run the sweep.
pub fn run(cfg: &Fig1Config) -> Vec<Fig1Point> {
    let mut out = Vec::new();
    for &eps in &cfg.epsilons {
        for &delta in &cfg.deltas {
            let mut subopts = Vec::with_capacity(cfg.trials);
            let mut pulls_sum = 0u64;
            for t in 0..cfg.trials {
                let seed = cfg.seed
                    ^ (t as u64).wrapping_mul(0x9E37_79B9)
                    ^ ((eps * 1e4) as u64).wrapping_mul(31)
                    ^ ((delta * 1e4) as u64).wrapping_mul(131);
                let env = AdversarialArms::generate(cfg.n_arms, cfg.n_list, seed);
                let algo = BoundedMe::new(BoundedMeConfig { k: 1, epsilon: eps, delta });
                let res = algo.run(&env);
                let best = env.true_mean(env.best_arm());
                let got = env.true_mean(res.result.arms[0]);
                subopts.push((best - got).max(0.0));
                pulls_sum += res.result.total_pulls;
            }
            subopts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q_idx = (((1.0 - delta) * subopts.len() as f64).ceil() as usize)
                .clamp(1, subopts.len())
                - 1;
            let quantile = subopts[q_idx];
            let mean = subopts.iter().sum::<f64>() / subopts.len() as f64;
            out.push(Fig1Point {
                epsilon: eps,
                delta,
                quantile_subopt: quantile,
                mean_subopt: mean,
                mean_pulls: pulls_sum as f64 / cfg.trials as f64,
                holds: quantile <= eps,
            });
        }
    }
    out
}

/// Aggregate per-ε rows (averaging the quantile over δ values), which is
/// what the paper's Figure 1 plots.
pub fn per_epsilon(points: &[Fig1Point]) -> Vec<(f64, f64, bool)> {
    let mut eps_values: Vec<f64> = points.iter().map(|p| p.epsilon).collect();
    eps_values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    eps_values.dedup();
    eps_values
        .into_iter()
        .map(|e| {
            let group: Vec<&Fig1Point> =
                points.iter().filter(|p| (p.epsilon - e).abs() < 1e-12).collect();
            let avg =
                group.iter().map(|p| p.quantile_subopt).sum::<f64>() / group.len() as f64;
            let all_hold = group.iter().all(|p| p.holds);
            (e, avg, all_hold)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_guarantee_holds() {
        let cfg = Fig1Config {
            n_arms: 100,
            n_list: 300,
            epsilons: vec![0.2, 0.4],
            deltas: vec![0.1, 0.3],
            trials: 10,
            seed: 42,
        };
        let pts = run(&cfg);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.holds, "ε={} δ={}: quantile {}", p.epsilon, p.delta, p.quantile_subopt);
            assert!(p.mean_pulls > 0.0);
        }
        let agg = per_epsilon(&pts);
        assert_eq!(agg.len(), 2);
        assert!(agg.iter().all(|&(_, _, h)| h));
    }
}
