//! Precision-vs-speedup sweeps: the engine behind Figures 2, 3 and 4.
//!
//! For one dataset + query batch, every algorithm is swept over its
//! accuracy knob; each knob setting yields one `(precision@K,
//! online speedup)` point. "Online speedup" follows the paper: naive
//! query cost divided by the algorithm's query cost, with preprocessing
//! ignored (which only *favors* the baselines — Motivation I).

use crate::algos::{
    ground_truth, BoundedMeIndex, GreedyMipsIndex, LshMipsIndex, MipsIndex, MipsParams,
    PcaMipsIndex,
};
use crate::data::Dataset;
use crate::metrics::{precision_at_k, AlgoStats};
use std::time::Instant;

/// One point of a sweep curve.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Algorithm label.
    pub algo: String,
    /// Human-readable knob setting ("ε=0.1", "B=10%", "a=8,b=16", "d=4").
    pub knob: String,
    /// Mean precision@K over the query batch.
    pub precision: f64,
    /// Flop-based online speedup vs naive.
    pub speedup_flops: f64,
    /// Wall-clock online speedup vs naive.
    pub speedup_wall: f64,
    /// Mean candidates ranked (0 for BOUNDEDME).
    pub mean_candidates: f64,
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Result-set size K (paper: 5 and 10).
    pub k: usize,
    /// Number of queries per point.
    pub queries: usize,
    /// BOUNDEDME ε grid.
    pub bme_epsilons: Vec<f64>,
    /// BOUNDEDME δ.
    pub bme_delta: f64,
    /// GREEDY budgets as fractions of n.
    pub greedy_budgets: Vec<f64>,
    /// LSH (a, b) settings.
    pub lsh_settings: Vec<(usize, usize)>,
    /// PCA tree depths.
    pub pca_depths: Vec<usize>,
    /// Base seed.
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            k: 5,
            queries: 20,
            bme_epsilons: vec![0.01, 0.03, 0.1, 0.3, 0.6, 0.9],
            bme_delta: 0.1,
            greedy_budgets: vec![0.02, 0.05, 0.1, 0.25, 0.5, 1.0],
            lsh_settings: vec![(4, 8), (6, 12), (8, 16), (12, 24), (16, 32)],
            pca_depths: vec![1, 2, 4, 6, 8],
            seed: 0,
        }
    }
}

/// Evaluate one configured index over the query batch.
fn eval_index(
    index: &dyn MipsIndex,
    knob: &str,
    queries: &[Vec<f32>],
    truths: &[Vec<usize>],
    naive_flops: u64,
    naive_secs: f64,
    k: usize,
    seed: u64,
) -> SweepPoint {
    let mut stats = AlgoStats::new(index.name());
    let mut cand_sum = 0usize;
    let mut ctx = crate::exec::QueryContext::new();
    for (qi, (q, truth)) in queries.iter().zip(truths).enumerate() {
        let params = MipsParams { k, epsilon: 0.0, delta: 0.0, seed: seed ^ qi as u64 };
        // (ε, δ) for BOUNDEDME ride in via the knob-specific params below;
        // eval_index is called with pre-built indexes, so only BOUNDEDME
        // needs them — passed through `eval_bounded_me` instead.
        let t0 = Instant::now();
        let res = index.query_with(q, &params, &mut ctx);
        let dt = t0.elapsed().as_secs_f64();
        cand_sum += res.candidates;
        stats.record(
            precision_at_k(truth, &res.indices),
            res.flops,
            naive_flops,
            dt,
            naive_secs,
        );
    }
    SweepPoint {
        algo: index.name().to_string(),
        knob: knob.to_string(),
        precision: stats.precision(),
        speedup_flops: stats.speedup_flops(),
        speedup_wall: stats.speedup_wall(),
        mean_candidates: cand_sum as f64 / queries.len().max(1) as f64,
    }
}

/// Run the full sweep for a dataset. `queries` overrides the dataset's
/// query sampler when provided (Figure 4 uses genuine user factors).
pub fn run_sweep(
    ds: &Dataset,
    cfg: &SweepConfig,
    queries_override: Option<&[Vec<f32>]>,
) -> Vec<SweepPoint> {
    let queries: Vec<Vec<f32>> = match queries_override {
        Some(qs) => qs.iter().take(cfg.queries).cloned().collect(),
        None => ds.sample_queries(cfg.queries, cfg.seed),
    };
    let n = ds.n();

    // Ground truth + naive cost baseline.
    let t0 = Instant::now();
    let truths: Vec<Vec<usize>> =
        queries.iter().map(|q| ground_truth(&ds.vectors, q, cfg.k)).collect();
    let naive_secs_total = t0.elapsed().as_secs_f64();
    let naive_secs = naive_secs_total / queries.len().max(1) as f64;
    let naive_flops = (n * ds.dim()) as u64;

    let mut out = Vec::new();

    // BOUNDEDME sweep over ε (per-query knob — one shared zero-prep
    // index), in both pull orders: the paper's fully-permuted sampling
    // and the cache/TPU-friendly block-shuffled schedule.
    let bme_variants = [
        BoundedMeIndex::new(ds.vectors.clone()),
        BoundedMeIndex::with_order(
            ds.vectors.clone(),
            crate::bandit::PullOrder::BlockShuffled(64),
        ),
    ];
    let mut ctx = crate::exec::QueryContext::new();
    for bme in &bme_variants {
        for &eps in &cfg.bme_epsilons {
            let mut stats = AlgoStats::new(bme.name());
            let mut cand = 0usize;
            for (qi, (q, truth)) in queries.iter().zip(&truths).enumerate() {
                let params = MipsParams {
                    k: cfg.k,
                    epsilon: eps,
                    delta: cfg.bme_delta,
                    seed: cfg.seed ^ (qi as u64).wrapping_mul(6364136223846793005),
                };
                let t = Instant::now();
                let res = bme.query_with(q, &params, &mut ctx);
                stats.record(
                    precision_at_k(truth, &res.indices),
                    res.flops,
                    naive_flops,
                    t.elapsed().as_secs_f64(),
                    naive_secs,
                );
                cand += res.candidates;
            }
            out.push(SweepPoint {
                algo: bme.name().into(),
                knob: format!("eps={eps}"),
                precision: stats.precision(),
                speedup_flops: stats.speedup_flops(),
                speedup_wall: stats.speedup_wall(),
                mean_candidates: cand as f64 / queries.len().max(1) as f64,
            });
        }
    }

    // GREEDY-MIPS over budget.
    for &frac in &cfg.greedy_budgets {
        let budget = ((n as f64 * frac) as usize).max(1);
        let idx = GreedyMipsIndex::new(ds.vectors.clone(), budget);
        out.push(eval_index(
            &idx,
            &format!("B={:.0}%", frac * 100.0),
            &queries,
            &truths,
            naive_flops,
            naive_secs,
            cfg.k,
            cfg.seed,
        ));
    }

    // LSH-MIPS over (a, b).
    for &(a, b) in &cfg.lsh_settings {
        let idx = LshMipsIndex::new(ds.vectors.clone(), a, b, cfg.seed ^ 0xD00D);
        out.push(eval_index(
            &idx,
            &format!("a={a},b={b}"),
            &queries,
            &truths,
            naive_flops,
            naive_secs,
            cfg.k,
            cfg.seed,
        ));
    }

    // PCA-MIPS over depth.
    for &d in &cfg.pca_depths {
        if (1usize << d) > n {
            continue;
        }
        let idx = PcaMipsIndex::new(ds.vectors.clone(), d, cfg.seed ^ 0xBEEF);
        out.push(eval_index(
            &idx,
            &format!("d={d}"),
            &queries,
            &truths,
            naive_flops,
            naive_secs,
            cfg.k,
            cfg.seed,
        ));
    }

    out
}

/// Format sweep points as the example binaries print them.
pub fn format_points(points: &[SweepPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.algo.clone(),
                p.knob.clone(),
                format!("{:.3}", p.precision),
                format!("{:.2}x", p.speedup_flops),
                format!("{:.2}x", p.speedup_wall),
                format!("{:.1}", p.mean_candidates),
            ]
        })
        .collect();
    super::markdown_table(
        &["algo", "knob", "precision", "speedup(flops)", "speedup(wall)", "candidates"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::gaussian_dataset;

    #[test]
    fn tiny_sweep_produces_sane_points() {
        let ds = gaussian_dataset(150, 64, 3);
        let cfg = SweepConfig {
            k: 3,
            queries: 4,
            bme_epsilons: vec![0.05, 0.5],
            greedy_budgets: vec![0.5],
            lsh_settings: vec![(4, 6)],
            pca_depths: vec![2],
            ..Default::default()
        };
        let pts = run_sweep(&ds, &cfg, None);
        // 2 BoundedME variants × 2 ε + greedy + lsh + pca.
        assert_eq!(pts.len(), 7);
        for p in &pts {
            assert!((0.0..=1.0).contains(&p.precision), "{p:?}");
            assert!(p.speedup_flops > 0.0);
        }
        // Tight ε must give higher precision than loose ε.
        let tight = &pts[0];
        let loose = &pts[1];
        assert!(tight.precision >= loose.precision - 1e-9);
        // Table formatting runs.
        let s = format_points(&pts);
        assert!(s.contains("BoundedME"));
    }
}
