//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the Rust hot path.
//!
//! The compile path is Python (`python/compile/aot.py` lowers the L2 JAX
//! model — which calls the L1 Pallas kernel — to **HLO text**); the
//! serve path is Rust only: [`Runtime`] parses the text with
//! `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//! client, and [`Runtime::execute_f32`] runs it with concrete buffers.
//!
//! HLO *text* (not serialized protos) is the interchange format: jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! PJRT objects are not `Send`/`Sync`, so [`engine::PjrtEngine`] wraps a
//! dedicated owner thread behind a cloneable handle — the coordinator
//! talks to it through a channel.
//!
//! The whole PJRT path sits behind the off-by-default `pjrt` cargo
//! feature (the `xla` bindings are not available in the offline build
//! image). With the feature off, [`Runtime`] is absent, artifact-name
//! parsing still works, and `PjrtEngine` is a stub whose constructors
//! fail — callers fall back to [`engine::NativeEngine`].

pub mod engine;

pub use engine::{NativeEngine, PjrtEngine, ScoringEngine};

#[cfg(feature = "pjrt")]
use crate::errors::{anyhow, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// Shape signature of an artifact, parsed from its file name.
///
/// Naming convention (produced by `python/compile/aot.py`):
/// * `exact_b{B}_d{D}.hlo.txt` — inputs `V[B,D] f32, q[D] f32`,
///   output `(scores[B],)`: exact inner products of a block of `B`
///   vectors against one query.
/// * `partial_b{B}_c{C}.hlo.txt` — inputs `V[B,C], q[C]`, output
///   `(sums[B],)`: one BOUNDEDME pull batch (a `C`-coordinate slab).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactShape {
    /// Block size `B` (rows per call).
    pub block: usize,
    /// Coordinate width (full `D` for exact, chunk `C` for partial).
    pub width: usize,
    /// True for `partial_*` artifacts.
    pub partial: bool,
}

/// Parse an artifact file name into its shape, if it follows the
/// convention.
pub fn parse_artifact_name(name: &str) -> Option<ArtifactShape> {
    let stem = name.strip_suffix(".hlo.txt").unwrap_or(name);
    let (kind, rest) = stem.split_once("_b")?;
    let partial = match kind {
        "exact" => false,
        "partial" => true,
        _ => return None,
    };
    let (b_str, w_str) = if partial {
        rest.split_once("_c")?
    } else {
        rest.split_once("_d")?
    };
    Some(ArtifactShape {
        block: b_str.parse().ok()?,
        width: w_str.parse().ok()?,
        partial,
    })
}

#[cfg(feature = "pjrt")]
struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    shape: ArtifactShape,
}

/// A PJRT CPU client plus a cache of compiled artifacts. **Not** `Send`:
/// keep it on one thread (see [`engine::PjrtEngine`] for the threaded
/// wrapper). Only available with the `pjrt` feature.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self { client, artifacts: HashMap::new() })
    }

    /// Load and compile one artifact file under the given name.
    pub fn load_artifact(&mut self, name: &str, path: &Path) -> Result<()> {
        let shape = parse_artifact_name(
            path.file_name().and_then(|s| s.to_str()).unwrap_or(name),
        )
        .or_else(|| parse_artifact_name(name))
        .ok_or_else(|| anyhow!("artifact name {name:?} not parseable"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.artifacts.insert(name.to_string(), LoadedArtifact { exe, shape });
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory; returns the number loaded.
    pub fn load_dir(&mut self, dir: impl AsRef<Path>) -> Result<usize> {
        let dir = dir.as_ref();
        let mut count = 0;
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("read_dir {dir:?}"))?
        {
            let path: PathBuf = entry?.path();
            let Some(fname) = path.file_name().and_then(|s| s.to_str()) else { continue };
            if !fname.ends_with(".hlo.txt") {
                continue;
            }
            let name = fname.trim_end_matches(".hlo.txt").to_string();
            if parse_artifact_name(fname).is_some() {
                self.load_artifact(&name, &path)?;
                count += 1;
            }
        }
        Ok(count)
    }

    /// Names of loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Shape of a loaded artifact.
    pub fn shape_of(&self, name: &str) -> Option<ArtifactShape> {
        self.artifacts.get(name).map(|a| a.shape)
    }

    /// Find the exact-scoring artifact whose width equals `dim`, if any
    /// (largest block wins — best for whole-dataset scans).
    pub fn find_exact(&self, dim: usize) -> Option<(String, ArtifactShape)> {
        self.artifacts
            .iter()
            .filter(|(_, a)| !a.shape.partial && a.shape.width == dim)
            .map(|(n, a)| (n.clone(), a.shape))
            .max_by_key(|(_, s)| s.block)
    }

    /// Like [`Runtime::find_exact`] but preferring the *smallest* block —
    /// best for ad-hoc small row batches (less padding waste).
    pub fn find_exact_min(&self, dim: usize) -> Option<(String, ArtifactShape)> {
        self.artifacts
            .iter()
            .filter(|(_, a)| !a.shape.partial && a.shape.width == dim)
            .map(|(n, a)| (n.clone(), a.shape))
            .min_by_key(|(_, s)| s.block)
    }

    /// Find the partial-scoring artifact with the given chunk width.
    pub fn find_partial(&self, width: usize) -> Option<(String, ArtifactShape)> {
        self.artifacts
            .iter()
            .filter(|(_, a)| a.shape.partial && a.shape.width == width)
            .map(|(n, a)| (n.clone(), a.shape))
            .max_by_key(|(_, s)| s.block)
    }

    /// Upload an f32 tensor to the device once; the returned buffer can
    /// be reused across [`Runtime::execute_buffers`] calls (how the
    /// serving engine keeps the static dataset resident instead of
    /// re-copying it per query).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Execute a loaded artifact over pre-uploaded device buffers.
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<f32>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let result = art
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("execute_b {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute a loaded artifact with f32 inputs (`(data, dims)` pairs)
    /// and return the flattened f32 output of its 1-tuple result.
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expected: usize = dims.iter().product();
            if expected != data.len() {
                return Err(anyhow!(
                    "input shape {dims:?} wants {expected} elements, got {}",
                    data.len()
                ));
            }
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            lits.push(lit);
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_artifact_names() {
        assert_eq!(
            parse_artifact_name("exact_b256_d512.hlo.txt"),
            Some(ArtifactShape { block: 256, width: 512, partial: false })
        );
        assert_eq!(
            parse_artifact_name("partial_b128_c64.hlo.txt"),
            Some(ArtifactShape { block: 128, width: 64, partial: true })
        );
        assert_eq!(parse_artifact_name("model.hlo.txt"), None);
        assert_eq!(parse_artifact_name("weird_bX_dY.hlo.txt"), None);
    }

    #[test]
    fn bare_names_parse_too() {
        assert_eq!(
            parse_artifact_name("exact_b8_d16"),
            Some(ArtifactShape { block: 8, width: 16, partial: false })
        );
    }
}
