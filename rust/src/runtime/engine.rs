//! Scoring engines: the pluggable compute backends of the coordinator.
//!
//! [`ScoringEngine`] is the contract the serving layer programs against:
//! "score a block of vectors against one query" — plus the fused
//! multi-query entry points the batched execution core uses
//! ([`ScoringEngine::score_batch_into`] /
//! [`ScoringEngine::score_dataset_batch`]), so a whole dynamic batch is
//! one engine call instead of per-query chunked loops. Two
//! implementations:
//!
//! * [`NativeEngine`] — pure-Rust blocked dot products (no PJRT), with a
//!   row-major fused kernel for query batches (each dataset row is
//!   loaded once and dotted against every query while hot in cache);
//! * [`PjrtEngine`] — routes blocks to the AOT-compiled XLA artifact on
//!   a dedicated owner thread (PJRT handles are not `Send`), padding to
//!   the artifact's fixed block size. Behind the `pjrt` feature; the
//!   stub built without it fails at construction so callers fall back
//!   to native.
//!
//! The `hotpath` bench compares them head-to-head; the coordinator picks
//! per `CoordinatorConfig::backend`.

use crate::errors::{anyhow, Result};
use crate::linalg::{dot_rows, Matrix};
use std::path::PathBuf;

/// Block scorer: exact inner products of `rows` (flattened `count × dim`)
/// against `q` (`dim`).
pub trait ScoringEngine: Send {
    /// Engine label for metrics.
    fn name(&self) -> &str;
    /// Compute `count` inner products. `rows.len() == count * q.len()`.
    fn score_block(&self, rows: &[f32], count: usize, q: &[f32]) -> Result<Vec<f32>>;

    /// Fused multi-query scoring into a caller-owned buffer: scores of
    /// every row against every query, laid out query-major
    /// (`out[qi * count + i]` = row `i` · query `qi`). This is the one
    /// engine call a coordinator worker makes per dynamic batch. The
    /// default loops [`ScoringEngine::score_block`]; engines override it
    /// with genuinely fused kernels.
    fn score_batch_into(
        &self,
        rows: &[f32],
        count: usize,
        dim: usize,
        queries: &[&[f32]],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if rows.len() != count * dim {
            return Err(anyhow!("block shape mismatch: {} vs {count}×{dim}", rows.len()));
        }
        out.clear();
        out.reserve(queries.len() * count);
        for q in queries {
            if q.len() != dim {
                return Err(anyhow!("query dim {} != block dim {dim}", q.len()));
            }
            out.extend(self.score_block(rows, count, q)?);
        }
        Ok(())
    }

    /// Score every dataset row against every query of a batch
    /// (query-major output, like [`ScoringEngine::score_batch_into`]).
    /// Engines that keep the dataset resident on a device override this
    /// to skip the host-side row copy per call.
    fn score_dataset_batch(
        &self,
        data: &Matrix,
        queries: &[&[f32]],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.score_batch_into(data.as_slice(), data.rows(), data.cols(), queries, out)
    }

    /// Score whole matrix rows by index (convenience over
    /// [`ScoringEngine::score_block`], chunked to a reasonable block).
    fn score_rows(&self, data: &Matrix, ids: &[usize], q: &[f32]) -> Result<Vec<f32>> {
        const CHUNK: usize = 256;
        let dim = data.cols();
        let mut out = Vec::with_capacity(ids.len());
        let mut buf = Vec::with_capacity(CHUNK * dim);
        for chunk in ids.chunks(CHUNK) {
            buf.clear();
            for &i in chunk {
                buf.extend_from_slice(data.row(i));
            }
            out.extend(self.score_block(&buf, chunk.len(), q)?);
        }
        Ok(out)
    }

    /// Score every row of the dataset against `q`. Engines that keep the
    /// dataset resident on the device (see [`PjrtEngine::with_dataset`])
    /// override this to skip the per-call data copy.
    fn score_dataset(&self, data: &Matrix, q: &[f32]) -> Result<Vec<f32>> {
        let ids: Vec<usize> = (0..data.rows()).collect();
        self.score_rows(data, &ids, q)
    }
}

/// Pure-Rust scorer, built on the runtime-dispatched blocked SIMD
/// kernels ([`crate::linalg::simd`]); tiles by the shared
/// [`crate::linalg::simd::SCAN_TILE`] so it tunes together with the
/// Naive fused scan.
pub struct NativeEngine;

impl ScoringEngine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn score_block(&self, rows: &[f32], count: usize, q: &[f32]) -> Result<Vec<f32>> {
        let dim = q.len();
        if rows.len() != count * dim {
            return Err(anyhow!("block shape mismatch: {} vs {count}×{dim}", rows.len()));
        }
        let mut out = vec![0f32; count];
        dot_rows(rows, dim, q, &mut out);
        Ok(out)
    }

    /// Row-major fused kernel: one pass over the rows in
    /// [`crate::linalg::simd::SCAN_TILE`]-row tiles, each tile scored
    /// against every query while resident in cache. On a `B`-query
    /// batch this reads the dataset once instead of `B` times, and the
    /// blocked `dot_rows` kernel shares each query register load across
    /// the tile's rows.
    fn score_batch_into(
        &self,
        rows: &[f32],
        count: usize,
        dim: usize,
        queries: &[&[f32]],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        if rows.len() != count * dim {
            return Err(anyhow!("block shape mismatch: {} vs {count}×{dim}", rows.len()));
        }
        for q in queries {
            if q.len() != dim {
                return Err(anyhow!("query dim {} != block dim {dim}", q.len()));
            }
        }
        out.clear();
        out.resize(queries.len() * count, 0.0);
        let mut base = 0usize;
        while base < count {
            let take = (count - base).min(crate::linalg::simd::SCAN_TILE);
            let block = &rows[base * dim..(base + take) * dim];
            for (qi, q) in queries.iter().enumerate() {
                let dst = &mut out[qi * count + base..qi * count + base + take];
                dot_rows(block, dim, q, dst);
            }
            base += take;
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;
    use crate::runtime::Runtime;
    use std::sync::mpsc;
    use std::thread::JoinHandle;

    enum Cmd {
        Score { rows: Vec<f32>, count: usize, q: Vec<f32>, reply: mpsc::Sender<Result<Vec<f32>>> },
        ScoreResident { q: Vec<f32>, reply: mpsc::Sender<Result<Vec<f32>>> },
        Shutdown,
    }

    /// PJRT-backed scorer. Owns a worker thread holding the [`Runtime`];
    /// the handle is `Send` and cheap to share behind an `Arc`.
    pub struct PjrtEngine {
        tx: mpsc::Sender<Cmd>,
        handle: Option<JoinHandle<()>>,
        label: String,
        /// Rows preloaded on the device (0 = none).
        resident_rows: usize,
    }

    impl PjrtEngine {
        /// Spawn the owner thread, load artifacts from `artifact_dir`, and
        /// require an `exact_b*_d{dim}` artifact to exist for this `dim`.
        pub fn new(artifact_dir: impl Into<PathBuf>, dim: usize) -> Result<Self> {
            Self::spawn(artifact_dir.into(), dim, None)
        }

        /// Like [`PjrtEngine::new`], but uploads the dataset to the device
        /// once at startup; [`ScoringEngine::score_dataset`] then only moves
        /// the query per call (the big win on the serving hot path — see the
        /// `hotpath` bench and EXPERIMENTS.md §Perf).
        pub fn with_dataset(
            artifact_dir: impl Into<PathBuf>,
            data: &Matrix,
        ) -> Result<Self> {
            Self::spawn(artifact_dir.into(), data.cols(), Some(data.clone()))
        }

        fn spawn(dir: PathBuf, dim: usize, preload: Option<Matrix>) -> Result<Self> {
            let resident_rows = preload.as_ref().map_or(0, |m| m.rows());
            let (tx, rx) = mpsc::channel::<Cmd>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();
            let handle = std::thread::Builder::new()
                .name("pjrt-engine".into())
                .spawn(move || {
                    // Initialize the runtime on the owner thread. Ad-hoc
                    // copies use the smallest block artifact (minimal
                    // padding); the resident dataset uses the largest
                    // (fewest dispatches).
                    type Resident = Vec<xla::PjRtBuffer>;
                    struct Init {
                        rt: Runtime,
                        small: (String, usize),
                        big: (String, usize),
                        resident: Resident,
                    }
                    let init = (|| -> Result<Init> {
                        let mut rt = Runtime::cpu()?;
                        rt.load_dir(&dir)?;
                        let (small_name, small_shape) = rt
                            .find_exact_min(dim)
                            .ok_or_else(|| anyhow!("no exact_b*_d{dim} artifact in {dir:?}"))?;
                        let (big_name, big_shape) = rt.find_exact(dim).unwrap();
                        // Upload the dataset block-by-block (padded tail).
                        let mut resident = Vec::new();
                        if let Some(data) = &preload {
                            let block = big_shape.block;
                            let mut padded = vec![0f32; block * dim];
                            let n = data.rows();
                            let mut i = 0usize;
                            while i < n {
                                let take = (n - i).min(block);
                                padded[..take * dim]
                                    .copy_from_slice(&data.as_slice()[i * dim..(i + take) * dim]);
                                padded[take * dim..].fill(0.0);
                                resident.push(rt.upload_f32(&padded, &[block, dim])?);
                                i += take;
                            }
                        }
                        Ok(Init {
                            rt,
                            small: (small_name, small_shape.block),
                            big: (big_name, big_shape.block),
                            resident,
                        })
                    })();
                    let Init { rt, small, big, resident } = match init {
                        Ok(v) => {
                            let _ = ready_tx.send(Ok(v.small.0.clone()));
                            v
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    while let Ok(cmd) = rx.recv() {
                        match cmd {
                            Cmd::Shutdown => break,
                            Cmd::Score { rows, count, q, reply } => {
                                let res =
                                    score_padded(&rt, &small.0, small.1, dim, &rows, count, &q);
                                let _ = reply.send(res);
                            }
                            Cmd::ScoreResident { q, reply } => {
                                let res = (|| -> Result<Vec<f32>> {
                                    let qbuf = rt.upload_f32(&q, &[dim])?;
                                    let mut out = Vec::with_capacity(resident.len() * big.1);
                                    for vbuf in &resident {
                                        out.extend(rt.execute_buffers(&big.0, &[vbuf, &qbuf])?);
                                    }
                                    Ok(out)
                                })();
                                let _ = reply.send(res);
                            }
                        }
                    }
                })?;
            let loaded = ready_rx
                .recv()
                .map_err(|_| anyhow!("pjrt engine thread died during init"))??;
            Ok(Self {
                tx,
                handle: Some(handle),
                label: format!("pjrt[{loaded}]"),
                resident_rows,
            })
        }

        /// Rows preloaded on the device.
        pub fn resident_rows(&self) -> usize {
            self.resident_rows
        }
    }

    /// Execute the exact artifact over `count` rows, padding each block to
    /// the artifact's fixed `block` rows.
    fn score_padded(
        rt: &Runtime,
        artifact: &str,
        block: usize,
        dim: usize,
        rows: &[f32],
        count: usize,
        q: &[f32],
    ) -> Result<Vec<f32>> {
        if q.len() != dim {
            return Err(anyhow!("query dim {} != artifact dim {dim}", q.len()));
        }
        if rows.len() != count * dim {
            return Err(anyhow!("block shape mismatch"));
        }
        let mut out = Vec::with_capacity(count);
        let mut padded = vec![0f32; block * dim];
        let mut i = 0usize;
        while i < count {
            let take = (count - i).min(block);
            let src = &rows[i * dim..(i + take) * dim];
            if take == block {
                let scores =
                    rt.execute_f32(artifact, &[(src, &[block, dim]), (q, &[dim])])?;
                out.extend_from_slice(&scores[..take]);
            } else {
                padded[..src.len()].copy_from_slice(src);
                padded[src.len()..].fill(0.0);
                let scores =
                    rt.execute_f32(artifact, &[(&padded, &[block, dim]), (q, &[dim])])?;
                out.extend_from_slice(&scores[..take]);
            }
            i += take;
        }
        Ok(out)
    }

    impl ScoringEngine for PjrtEngine {
        fn name(&self) -> &str {
            &self.label
        }

        fn score_block(&self, rows: &[f32], count: usize, q: &[f32]) -> Result<Vec<f32>> {
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Cmd::Score { rows: rows.to_vec(), count, q: q.to_vec(), reply })
                .map_err(|_| anyhow!("pjrt engine thread gone"))?;
            rx.recv().map_err(|_| anyhow!("pjrt engine dropped reply"))?
        }

        fn score_dataset(&self, data: &Matrix, q: &[f32]) -> Result<Vec<f32>> {
            if self.resident_rows != data.rows() {
                // Not preloaded (or a different dataset): fall back to the
                // copying path.
                let ids: Vec<usize> = (0..data.rows()).collect();
                return self.score_rows(data, &ids, q);
            }
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Cmd::ScoreResident { q: q.to_vec(), reply })
                .map_err(|_| anyhow!("pjrt engine thread gone"))?;
            let mut out = rx.recv().map_err(|_| anyhow!("pjrt engine dropped reply"))??;
            out.truncate(data.rows());
            Ok(out)
        }

        /// Per-query resident scans: the dataset stays on-device, only
        /// each query vector crosses the host boundary.
        fn score_dataset_batch(
            &self,
            data: &Matrix,
            queries: &[&[f32]],
            out: &mut Vec<f32>,
        ) -> Result<()> {
            out.clear();
            out.reserve(queries.len() * data.rows());
            for q in queries {
                out.extend(self.score_dataset(data, q)?);
            }
            Ok(())
        }
    }

    impl Drop for PjrtEngine {
        fn drop(&mut self) {
            let _ = self.tx.send(Cmd::Shutdown);
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtEngine;

/// Stub built without the `pjrt` feature: construction fails, so every
/// caller (coordinator workers, benches) falls back to [`NativeEngine`].
#[cfg(not(feature = "pjrt"))]
pub struct PjrtEngine {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtEngine {
    /// Always fails: the crate was built without PJRT support.
    pub fn new(_artifact_dir: impl Into<PathBuf>, _dim: usize) -> Result<Self> {
        Err(anyhow!("pjrt support not compiled in (enable the `pjrt` feature)"))
    }

    /// Always fails: the crate was built without PJRT support.
    pub fn with_dataset(_artifact_dir: impl Into<PathBuf>, _data: &Matrix) -> Result<Self> {
        Err(anyhow!("pjrt support not compiled in (enable the `pjrt` feature)"))
    }

    /// Rows preloaded on the device (always 0 for the stub).
    pub fn resident_rows(&self) -> usize {
        0
    }
}

#[cfg(not(feature = "pjrt"))]
impl ScoringEngine for PjrtEngine {
    fn name(&self) -> &str {
        "pjrt-disabled"
    }

    fn score_block(&self, _rows: &[f32], _count: usize, _q: &[f32]) -> Result<Vec<f32>> {
        Err(anyhow!("pjrt support not compiled in"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, Rng};

    #[test]
    fn native_engine_matches_dot() {
        let e = NativeEngine;
        let rows = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let q = [1.0f32, 0.5];
        let s = e.score_block(&rows, 3, &q).unwrap();
        assert_eq!(s, vec![2.0, 5.0, 8.0]);
        assert!(e.score_block(&rows, 2, &q).is_err());
    }

    #[test]
    fn score_rows_chunks_correctly() {
        let mut rng = Rng::new(1);
        let data = Matrix::from_fn(600, 8, |_, _| rng.gaussian() as f32);
        let q: Vec<f32> = rng.gaussian_vec(8);
        let ids: Vec<usize> = (0..600).rev().collect();
        let got = NativeEngine.score_rows(&data, &ids, &q).unwrap();
        for (pos, &i) in ids.iter().enumerate() {
            let expect = dot(data.row(i), &q);
            assert!((got[pos] - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn fused_batch_matches_per_query() {
        let mut rng = Rng::new(2);
        let data = Matrix::from_fn(97, 33, |_, _| rng.gaussian() as f32);
        let qs: Vec<Vec<f32>> = (0..5).map(|_| rng.gaussian_vec(33)).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let mut fused = Vec::new();
        NativeEngine.score_dataset_batch(&data, &qrefs, &mut fused).unwrap();
        assert_eq!(fused.len(), 5 * 97);
        for (qi, q) in qs.iter().enumerate() {
            let single = NativeEngine.score_block(data.as_slice(), 97, q).unwrap();
            assert_eq!(&fused[qi * 97..(qi + 1) * 97], single.as_slice(), "query {qi}");
        }
    }

    #[test]
    fn fused_batch_rejects_bad_shapes() {
        let rows = [0.0f32; 6];
        let q = [0.0f32; 2];
        let mut out = Vec::new();
        assert!(NativeEngine.score_batch_into(&rows, 2, 2, &[&q], &mut out).is_err());
        let q3 = [0.0f32; 3];
        assert!(NativeEngine.score_batch_into(&rows, 3, 2, &[&q3], &mut out).is_err());
    }

    #[test]
    fn default_score_batch_into_matches_fused() {
        // Drive the trait-default path through a wrapper engine that
        // only implements `score_block`.
        struct Plain;
        impl ScoringEngine for Plain {
            fn name(&self) -> &str {
                "plain"
            }
            fn score_block(&self, rows: &[f32], count: usize, q: &[f32]) -> Result<Vec<f32>> {
                NativeEngine.score_block(rows, count, q)
            }
        }
        let mut rng = Rng::new(3);
        let data = Matrix::from_fn(40, 16, |_, _| rng.gaussian() as f32);
        let qs: Vec<Vec<f32>> = (0..3).map(|_| rng.gaussian_vec(16)).collect();
        let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.as_slice()).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        Plain.score_dataset_batch(&data, &qrefs, &mut a).unwrap();
        NativeEngine.score_dataset_batch(&data, &qrefs, &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_stub_fails_to_construct() {
        assert!(PjrtEngine::new("/nonexistent", 16).is_err());
        let m = Matrix::zeros(2, 2);
        assert!(PjrtEngine::with_dataset("/nonexistent", &m).is_err());
    }
}
