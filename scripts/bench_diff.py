#!/usr/bin/env python3
"""Compare a fresh BENCH_*.json against a committed baseline.

Usage: bench_diff.py CURRENT BASELINE [--threshold 0.10]

Matches benchmark rows by (name, storage, churn, codec, offered_load) —
`storage` is the optional per-row tier tag the mixed-precision rows
carry ("f16", "int8", ...), `churn` the optional live-mutation rate tag
the serving churn rows carry ("0%", "1%", "10%"), `codec` the optional
wire-codec tag the serving wire rows carry ("json", "binary"),
`offered_load` the optional overload-sweep multiplier the anytime
degradation rows carry (1.0, 2.0, 4.0); untagged rows key on name alone
— and compares `mean_s`. Regressions beyond the threshold are printed
as GitHub advisory annotations (`::warning::`) so CI surfaces them
without failing the build — bench runners are noisy, a hard gate would
flap. Rows tagged `answered_within_deadline` (the serving overload
sweep) are quality rows, not latency rows: their `mean_s` is the
fraction of submitted queries answered within the deadline, so HIGHER
is better and the regression test flips — a current fraction more than
the threshold below baseline warns. Rows with no baseline counterpart (newly added
benches, e.g. `pull_panel/*` before the next scheduled baseline refresh)
are informational only: they are listed in one `::notice::` annotation
and never diffed or counted as regressions. Exits 0 always unless the
current file is missing/unreadable (exit 2), so the CI step stays
advisory.

If the baseline file does not exist, prints a notice and exits 0: the
first run on a branch has nothing to diff against. Commit the produced
BENCH_*.json files under rust/benches/baseline/ to establish one.
"""

import json
import sys


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    return {
        (
            row["name"],
            row.get("storage", ""),
            row.get("churn", ""),
            row.get("codec", ""),
            str(row.get("offered_load", "")),
        ): row
        for row in doc.get("results", [])
    }


def label(key):
    name, storage, churn, codec, load = key
    if load:
        load = f"load={load}x"
    tags = "/".join(t for t in (storage, churn, codec, load) if t)
    return f"{name} [{tags}]" if tags else name


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    threshold = 0.10
    for a in argv[1:]:
        if a.startswith("--threshold"):
            threshold = float(a.split("=", 1)[1] if "=" in a else argv[argv.index(a) + 1])
    if len(args) < 2:
        print(__doc__)
        return 2
    current_path, baseline_path = args[0], args[1]

    try:
        current = load_rows(current_path)
    except OSError as e:
        print(f"::error::bench diff: cannot read current results {current_path}: {e}")
        return 2

    try:
        baseline = load_rows(baseline_path)
    except OSError:
        print(
            f"bench diff: no baseline at {baseline_path} — skipping comparison. "
            f"Commit {current_path} there to start tracking the trajectory."
        )
        return 0

    regressions = 0
    missing_baseline = []
    for key, row in sorted(current.items()):
        base = baseline.get(key)
        if base is None:
            missing_baseline.append(label(key))
            continue
        cur_mean, base_mean = row.get("mean_s"), base.get("mean_s")
        if not cur_mean or not base_mean:
            continue
        ratio = cur_mean / base_mean
        delta_pct = (ratio - 1.0) * 100.0
        if "answered_within_deadline" in row or "answered_within_deadline" in base:
            # Quality row: mean_s is the answered-within-deadline
            # fraction — higher is better, so the direction flips.
            if ratio < 1.0 - threshold:
                regressions += 1
                print(
                    f"::warning title=answered-within-deadline regression::"
                    f"{label(key)}: {base_mean:.3f} -> {cur_mean:.3f} "
                    f"answered fraction ({delta_pct:+.1f}%)"
                )
            else:
                print(
                    f"bench diff: {label(key)}: {delta_pct:+.1f}% "
                    f"(answered fraction, higher is better)"
                )
        elif ratio > 1.0 + threshold:
            regressions += 1
            print(
                f"::warning title=bench regression::{label(key)}: "
                f"{base_mean * 1e3:.3f} ms "
                f"-> {cur_mean * 1e3:.3f} ms ({delta_pct:+.1f}%)"
            )
        else:
            print(f"bench diff: {label(key)}: {delta_pct:+.1f}%")
    for key in sorted(set(baseline) - set(current)):
        print(f"bench diff: benchmark {label(key)!r} disappeared from current run")
    if missing_baseline:
        names = ", ".join(missing_baseline)
        print(
            f"::notice title=new benchmarks (no baseline)::{len(missing_baseline)} "
            f"benchmark(s) have no baseline row and were not diffed: {names}. "
            "The scheduled refresh-bench-baseline job will pick them up."
        )
    print(
        f"bench diff: {regressions} regression(s) beyond {threshold * 100:.0f}% "
        f"across {len(current)} benchmark(s) "
        f"({len(missing_baseline)} informational, no baseline)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
